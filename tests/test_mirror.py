"""Device-resident full-set mirror: the jitted Alg. 6 reconstruction and
device-side un-shrink must reproduce the host-streaming paths bit-for-bit
(dense + ELL, cache on/off, wss1 + wss2, single-host + parallel), the
'auto' sizing must fall back cleanly under a tiny budget, 'device' must
error clearly instead of OOMing, save->resume must survive a mirrored
un-shrink, and the mirror gather plan must reproduce the host dealing
layout on arbitrary subsets."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import SMOSolver, SVMConfig, dataplane, rowcache, train
from repro.core import mirror as mirror_mod
from repro.core import kernel_fns
from repro.data import make_sparse
from test_distributed import run_sub

# wide-margin sparse problem: shrinks aggressively under the Multi policy,
# so every fit goes through >= 1 reconstruction AND >= 1 un-shrink growth —
# both mirror-backed steps — plus physical compactions
SHRINKY = dict(C=2.0, sigma2=40.0, heuristic="multi5pc", chunk_iters=64,
               min_buffer=64, eps=1e-3)


def _shrinky_data(n=900, d=300):
    return make_sparse(n, d, 0.05, seed=3, noise=0.05, label_noise=0.0,
                       margin=0.5)


# ------------------------------------------- device == host (the core test)
@pytest.mark.parametrize("fmt", ["dense", "ell"])
@pytest.mark.parametrize("cache", [False, True])
def test_mirror_bitwise_parity(fmt, cache):
    """mirror='device' (jitted scan reconstruction + device un-shrink) must
    reproduce mirror='host' (streaming oracle + host store rebuild)
    bit-for-bit: identical iteration counts, bitwise-equal alpha, identical
    buffer-geometry and cache trajectories — across >= 1 reconstruction."""
    X, y = _shrinky_data()
    kw = dict(format=fmt, row_cache=cache, **SHRINKY)
    md = train(X, y, mirror="device", **kw)
    mh = train(X, y, mirror="host", **kw)
    assert md.stats.mirror == "device" and mh.stats.mirror == "host"
    assert md.stats.reconstructions >= 1
    assert md.stats.compactions >= 1
    assert md.stats.converged
    assert md.stats.iterations == mh.stats.iterations
    np.testing.assert_array_equal(md.alpha, mh.alpha)
    assert md.stats.buffer_sizes == mh.stats.buffer_sizes
    assert md.stats.buffer_K == mh.stats.buffer_K
    assert md.stats.shard_K == mh.stats.shard_K
    assert md.stats.recon_time > 0 and mh.stats.recon_time > 0
    if cache:
        assert (md.stats.cache_hits, md.stats.cache_misses) \
            == (mh.stats.cache_hits, mh.stats.cache_misses)


def test_mirror_parity_wss2():
    """Second-order selection through both mirror backends — covers the
    selection-row k_ul reuse (the update prices the pair with the exact
    value the wss2 scores elected it by) on top of the mirror paths."""
    X, y = _shrinky_data(n=500, d=200)
    kw = dict(selection="wss2", row_cache=True, **SHRINKY)
    md = train(X, y, mirror="device", **kw)
    mh = train(X, y, mirror="host", **kw)
    m0 = train(X, y, mirror="device", **dict(kw, row_cache=False))
    assert md.stats.reconstructions >= 1
    assert md.stats.iterations == mh.stats.iterations
    np.testing.assert_array_equal(md.alpha, mh.alpha)
    # cache exactness holds through the reused selection row
    assert m0.stats.iterations == md.stats.iterations
    np.testing.assert_array_equal(m0.alpha, md.alpha)


def test_parallel_mirror_parity_4dev():
    out = run_sub("""
        import numpy as np, json
        from repro.core import SVMConfig
        from repro.core.parallel import ParallelSMOSolver
        from repro.data import make_sparse
        X, y = make_sparse(900, 300, 0.05, seed=3, noise=0.05,
                           label_noise=0.0, margin=0.5)
        kw = dict(C=2.0, sigma2=40.0, heuristic='multi5pc', chunk_iters=64,
                  min_buffer=64, row_cache=True)
        res = {}
        for fmt in ('dense', 'ell'):
            md = ParallelSMOSolver(SVMConfig(format=fmt, mirror='device',
                                             **kw)).fit(X, y)
            mh = ParallelSMOSolver(SVMConfig(format=fmt, mirror='host',
                                             **kw)).fit(X, y)
            res[fmt] = dict(
                modes=[md.stats.mirror, mh.stats.mirror],
                iters=[md.stats.iterations, mh.stats.iterations],
                recon=md.stats.reconstructions,
                alpha_eq=bool(np.array_equal(md.alpha, mh.alpha)),
                bufs_eq=md.stats.buffer_sizes == mh.stats.buffer_sizes,
                shard_K_eq=md.stats.shard_K == mh.stats.shard_K,
                conv=bool(md.stats.converged))
        print(json.dumps(res))
    """, devices=4)
    import json
    res = json.loads(out.strip().splitlines()[-1])
    for fmt in ("dense", "ell"):
        r = res[fmt]
        assert r["modes"] == ["device", "host"], r
        assert r["conv"], r
        assert r["recon"] >= 1, r                # mirror recon + growth hit
        assert r["iters"][0] == r["iters"][1], r
        assert r["alpha_eq"], r                  # bitwise
        assert r["bufs_eq"] and r["shard_K_eq"], r


# ------------------------------------------------------------ save -> resume
def test_resume_across_mirrored_unshrink(tmp_path):
    """Interrupt after >= 1 reconstruction (so the interrupted run grew its
    buffer from the device mirror); the resumed run — which rebuilds the
    mirror and gathers its initial subset buffer from it — must rejoin the
    uninterrupted trajectory."""
    X, y = _shrinky_data()
    full = train(X, y, mirror="device", **SHRINKY)
    assert full.stats.converged and full.stats.reconstructions >= 1
    cut = int(full.stats.iterations * 0.9)
    d = str(tmp_path)
    m1 = SMOSolver(SVMConfig(checkpoint_dir=d, max_iters=cut,
                             mirror="device", **SHRINKY)).fit(X, y)
    assert m1.stats.reconstructions >= 1, \
        "cut landed before the first mirrored un-shrink"
    assert m1.stats.iterations <= cut < full.stats.iterations
    m2 = SMOSolver(SVMConfig(checkpoint_dir=d, resume=True,
                             mirror="device", **SHRINKY)).fit(X, y)
    assert m2.stats.converged
    assert m2.stats.iterations == full.stats.iterations
    np.testing.assert_allclose(m2.alpha, full.alpha, atol=1e-6)


# --------------------------------------------------- gather-plan property
def test_mirror_grow_plan_property():
    """For random (n, p, subset) the device grow plan — compact_plan over
    mirror positions + gid gather — must reproduce the host dealing layout
    (``dataplane.deal``) exactly, including per-shard padding tails."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(deadline=None, max_examples=50)
    @given(st.integers(1, 4), st.integers(1, 40), st.data())
    def check(p, n, data):
        m_per_mir = mirror_mod.full_m_per(n, p, 4)
        midx = np.full((p * m_per_mir,), -1, np.int64)
        for sl, sub in dataplane.deal(np.arange(n), p, m_per_mir):
            midx[sl] = sub
        rows = np.flatnonzero(np.array(
            data.draw(st.lists(st.booleans(), min_size=n, max_size=n))))
        m_per = mirror_mod.full_m_per(rows.size, p, 4)
        keep = np.isin(midx, rows)
        src, valid = dataplane.compact_plan(
            jnp.asarray(keep), jnp.int32(rows.size), p=p, m_per=m_per)
        got = np.where(np.asarray(valid), midx[np.asarray(src)], -1)
        expect = np.full((p * m_per,), -1, np.int64)
        for sl, sub in dataplane.deal(rows, p, m_per):
            expect[sl] = sub
        np.testing.assert_array_equal(got, expect)

    check()


# ------------------------------------------------------- sizing / fallback
def test_auto_fallback_tiny_budget():
    """'auto' with a budget the mirror cannot fit falls back to the host
    paths — same result, stats.mirror records the fallback."""
    X, y = _shrinky_data(n=400, d=200)
    ma = train(X, y, mirror="auto", mirror_budget_bytes=1000, **SHRINKY)
    mh = train(X, y, mirror="host", **SHRINKY)
    assert ma.stats.mirror == "host"
    assert ma.stats.iterations == mh.stats.iterations
    np.testing.assert_array_equal(ma.alpha, mh.alpha)


def test_device_over_budget_errors_clearly():
    X, y = _shrinky_data(n=400, d=200)
    with pytest.raises(ValueError, match="mirror_budget_bytes"):
        train(X, y, mirror="device", mirror_budget_bytes=1000, **SHRINKY)
    with pytest.raises(ValueError, match="mirror"):
        train(X, y, mirror="gpu", **SHRINKY)


def test_csr_device_over_budget_names_lane_budget():
    """CSR ingest is where the full-set ELL mirror silently explodes (the
    lane budget densifies every row to K slots): the error must spell out
    the lane budget and byte counts instead of OOMing mid-fit."""
    from repro.data import to_csr
    X, y = _shrinky_data(n=400, d=200)
    csr = to_csr(X)
    with pytest.raises(ValueError, match="lane budget"):
        train(csr, y, format="ell", mirror="device",
              mirror_budget_bytes=1000, **SHRINKY)


def test_shrink_free_run_skips_mirror():
    """The 'none' policy never reconstructs or grows — the mirror resolves
    to 'host' (no dead device copy) and training is unaffected."""
    X, y = _shrinky_data(n=300, d=100)
    m = train(X, y, C=2.0, sigma2=40.0, heuristic="original",
              mirror="device")
    assert m.stats.mirror == "host"
    assert m.stats.reconstructions == 0


# ------------------------------------------------------- cache rewarming
def test_regrow_cache_semantics():
    """Rewarming preserves tags/recency/counters, zeroes untagged slots,
    and fills tagged slots with the provider's rows over the new buffer."""
    rng = np.random.default_rng(0)
    M, d, S = 16, 8, 4
    X = rng.normal(size=(M, d)).astype(np.float32)
    data = dataplane.DenseStore(X).to_device(
        X.copy(), jnp.asarray, gids=np.arange(M, dtype=np.int64),
        sq=(X * X).sum(1).astype(np.float32))
    provider = kernel_fns.make_provider("rbf", "dense", inv_2s2=0.25)
    c = rowcache.init_cache(S, 8)        # cache sized for an OLD buffer (8)
    c = c._replace(tags=jnp.asarray([3, -1, 11, 7], jnp.int32),
                   stamp=jnp.asarray([5, 0, 9, 2], jnp.int32),
                   hits=jnp.int32(4), misses=jnp.int32(6))
    w = rowcache.regrow_cache(c, data, provider, pairs=True, n=M)
    assert w.vals.shape == (S, M)        # resized to the grown buffer
    np.testing.assert_array_equal(w.tags, c.tags)
    np.testing.assert_array_equal(w.stamp, c.stamp)
    assert (int(w.hits), int(w.misses)) == (4, 6)
    np.testing.assert_array_equal(np.asarray(w.vals)[1], 0.0)  # untagged
    for slot, gid in ((0, 3), (2, 11), (3, 7)):
        ref = np.asarray(provider.row(data, jnp.asarray(X[gid])))
        np.testing.assert_allclose(np.asarray(w.vals)[slot], ref, atol=1e-6)


def test_warmed_cache_hits_after_unshrink():
    """End-to-end: a wss1 cached fit that reconstructs must keep serving
    hits right after the growth (the rewarm keeps the tags), and stay
    bitwise-exact against cache-off (already enforced broadly; asserted
    here on the mirrored path specifically)."""
    X, y = _shrinky_data()
    kw = dict(format="ell", mirror="device", **SHRINKY)
    m0 = train(X, y, **kw)
    m1 = train(X, y, row_cache=True, row_cache_slots=256, **kw)
    assert m1.stats.reconstructions >= 1
    assert m1.stats.iterations == m0.stats.iterations
    np.testing.assert_array_equal(m1.alpha, m0.alpha)
    assert m1.stats.cache_hits > 0
