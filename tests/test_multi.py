"""Batched multi-problem training (core/multi.py).

Parity: a problem's trajectory depends only on (X, y, C) — never on its
batch-mates, the batch width K, the row cache, or dispatch fusion — so
every batched configuration is compared BITWISE against the sequential
loop oracle (``multi_backend='loop'``), per problem. One sequential sweep
over the 8-point C grid serves as the oracle for every K (grids are
nested prefixes of ``CS``).

Accounting: the multi-problem FLOP split is pinned — kernel-row
production is billed once per physically produced row (cross-problem
cache hits produce nothing), the O(M) FMA epilogue once per
problem-iteration (a shared row still feeds K gamma updates).

Plus: (K, n) master checkpoint save -> resume mid-sweep, OvR union-engine
serving vs the per-model oracle, and a hypothesis property test that OvR
binarization + argmax voting is invariant under class-order permutation.
"""
import dataclasses
import os

import numpy as np
import pytest

from repro.core import (MultiProblemDriver, SMOSolver, SVMConfig,
                        ovr_tasks, train_ovr)

N, D = 384, 24
CS = np.geomspace(0.5, 8.0, 8)


def cfg(fmt="dense", sel="wss1", rc=False, fuse=1, **kw):
    kw = {"C": 1.0, "sigma2": 4.0, "eps": 1e-3, "heuristic": "multi5pc",
          "chunk_iters": 64, "min_buffer": 64, "row_cache_slots": 128,
          **kw}
    return SVMConfig(fuse_iters=fuse, format=fmt, selection=sel,
                     row_cache=rc, **kw)


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(7)
    X = rng.normal(size=(N, D)).astype(np.float32)
    X[rng.random(X.shape) < 0.5] = 0.0
    w = rng.normal(size=D)
    s = X @ w + 0.4 * rng.normal(size=N)
    y = np.where(s > np.median(s), 1.0, -1.0).astype(np.float32)
    return X, y


@pytest.fixture(scope="module")
def mdata():
    """3-class OvR dataset (integer labels)."""
    rng = np.random.default_rng(11)
    X = rng.normal(size=(180, 12)).astype(np.float32)
    w = rng.normal(size=(12, 3))
    y = np.argmax(X @ w + 0.5 * rng.normal(size=(180, 3)), axis=1)
    return X, y.astype(np.int32)


@pytest.fixture(scope="module")
def oracle(data):
    """Sequential loop-oracle models for every (fmt, sel) over the full
    8-point C grid; problem k of any batched K-fit compares to entry k."""
    X, y = data
    out = {}
    for fmt in ("dense", "ell"):
        for sel in ("wss1", "wss2"):
            out[(fmt, sel)] = MultiProblemDriver(
                cfg(fmt, sel), backend="loop").fit_tasks(
                    X, np.broadcast_to(y, (CS.size, N)).copy(), C=CS)
    return out


@pytest.mark.parametrize("fmt", ["dense", "ell"])
@pytest.mark.parametrize("sel", ["wss1", "wss2"])
@pytest.mark.parametrize("K", [1, 3, 8])
@pytest.mark.parametrize("rc,fuse", [(False, 1), (True, 1), (False, 8),
                                     (True, 8)])
def test_batched_equals_loop_oracle(data, oracle, fmt, sel, K, rc, fuse):
    X, y = data
    ms = oracle[(fmt, sel)]
    mb = MultiProblemDriver(cfg(fmt, sel, rc=rc, fuse=fuse)).fit_tasks(
        X, np.broadcast_to(y, (K, N)).copy(), C=CS[:K])
    st = mb[0].stats
    assert st.n_problems == K
    for k in range(K):
        # trajectory invariants: iterations, reconstructions, alpha
        # (bitwise), beta. Shrink-event COUNTS are deliberately not
        # compared: the batch compacts on the UNION of live problems'
        # active rows, so a lane's shrink countdown can be re-armed at a
        # different step than its solo run's own compaction would — an
        # extra Eq. 10 application that deactivates nothing new and
        # leaves the trajectory bit-identical.
        assert (st.per_problem[k]["iterations"]
                == ms[k].stats.iterations), (k, st.per_problem[k])
        assert (st.per_problem[k]["reconstructions"]
                == ms[k].stats.reconstructions), (k, st.per_problem[k])
        assert np.array_equal(mb[k].alpha, ms[k].alpha), k
        assert mb[k].beta == pytest.approx(ms[k].beta, abs=1e-6), k


def test_flop_accounting_production_once_epilogue_k_times(data):
    """Two IDENTICAL problems batched with the shared cache: every row
    the second lane asks for is the first lane's row, so production FLOPs
    must come in strictly under 2x the single-problem fit while the FMA
    epilogue is billed exactly per problem-iteration."""
    X, y = data
    base = cfg(rc=True, heuristic="original")   # no shrink: m constant
    m1 = MultiProblemDriver(base).fit_tasks(
        X, np.broadcast_to(y, (1, N)).copy())
    m2 = MultiProblemDriver(base).fit_tasks(
        X, np.broadcast_to(y, (2, N)).copy())
    s1, s2 = m1[0].stats, m2[0].stats
    it1 = s1.per_problem[0]["iterations"]
    assert [r["iterations"] for r in s2.per_problem] == [it1, it1]
    # the split is exhaustive (fp association across dispatches aside)
    assert s2.flops_est == pytest.approx(
        s2.flops_production + s2.flops_epilogue, rel=1e-12)
    # epilogue: per problem-iteration -> exactly doubles
    assert s2.flops_epilogue == 2 * s1.flops_epilogue
    # production: per physically produced row -> cross-problem hits are
    # free, strictly less than two independent fits pay
    assert s2.flops_production < 2 * s1.flops_production
    assert s2.cache_hit_rate > s1.cache_hit_rate
    # cache-off production for the same trajectory bills 2K rows per
    # joint iteration — an upper bound the cached run must undercut
    m2off = MultiProblemDriver(
        dataclasses.replace(base, row_cache=False)).fit_tasks(
            X, np.broadcast_to(y, (2, N)).copy())
    s2off = m2off[0].stats
    assert s2off.flops_epilogue == s2.flops_epilogue
    assert s2.flops_production < s2off.flops_production


def test_ckpt_save_resume_mid_sweep(tmp_path, data):
    X, y = data
    Y = np.broadcast_to(y, (3, N)).copy()
    Cs = np.asarray([0.5, 2.0, 8.0])
    full = MultiProblemDriver(cfg()).fit_tasks(X, Y, C=Cs)
    cut = max(r["iterations"]
              for r in full[0].stats.per_problem) // 2
    d = str(tmp_path)
    MultiProblemDriver(
        dataclasses.replace(cfg(), checkpoint_dir=d,
                            max_iters=cut)).fit_tasks(X, Y, C=Cs)
    assert os.path.exists(os.path.join(d, "multi_masters.npz"))
    m2 = MultiProblemDriver(
        dataclasses.replace(cfg(), checkpoint_dir=d,
                            resume=True)).fit_tasks(X, Y, C=Cs)
    st = m2[0].stats
    assert st.converged
    for k in range(3):
        np.testing.assert_allclose(m2[k].alpha, full[k].alpha, atol=1e-6)
        ref = full[k].dual_objective()
        assert abs(m2[k].dual_objective() - ref) / abs(ref) < 1e-3
    # a checkpoint is bound to its (K, n): resuming a different sweep
    # shape must refuse, not silently mis-map problems
    with pytest.raises(ValueError):
        MultiProblemDriver(
            dataclasses.replace(cfg(), checkpoint_dir=d,
                                resume=True)).fit_tasks(
                                    X, Y[:2], C=Cs[:2])


@pytest.mark.parametrize("fmt", ["dense", "ell"])
def test_ovr_union_engine_matches_per_model_oracle(mdata, fmt):
    X, y = mdata
    mdl = MultiProblemDriver(cfg(fmt)).fit_ovr(X, y)
    assert mdl._union is not None
    eng = mdl.union_engine()
    assert eng.n_out == len(mdl.classes) == 3
    got = np.asarray(eng.decision_function(X))
    ref = mdl.decision_matrix_host(X)
    assert got.shape == ref.shape == (len(X), 3)
    np.testing.assert_allclose(got, ref, atol=1e-4)
    pred = mdl.predict(X)
    assert pred.shape == (len(X),)
    assert set(np.unique(pred)) <= set(mdl.classes.tolist())
    assert (pred == np.asarray(mdl.classes)[np.argmax(ref, axis=1)]).all()


def test_train_ovr_wrapper_and_grid(mdata):
    X, y = mdata
    mdl = train_ovr(X, y, C=1.0, sigma2=4.0, eps=1e-3,
                    heuristic="multi5pc", chunk_iters=64, min_buffer=64)
    assert (mdl.predict(X) == y).mean() > 0.8
    classes, Y = ovr_tasks(y)
    assert classes.tolist() == [0, 1, 2] and Y.shape == (3, len(X))
    # grid with mixed sigma2 groups trains per-sigma2 batches, returns in
    # grid order with per-point C
    models = MultiProblemDriver(cfg()).fit_grid(
        X[:120], np.where(y[:120] == 0, 1.0, -1.0).astype(np.float32),
        Cs=[0.5, 4.0, 0.5, 4.0], sigma2s=[4.0, 4.0, 8.0, 8.0])
    assert len(models) == 4
    assert [m.config.C for m in models] == [0.5, 4.0, 0.5, 4.0]
    assert [m.config.sigma2 for m in models] == [4.0, 4.0, 8.0, 8.0]


def test_ovr_vote_permutation_invariant(mdata):
    """OvR binarization + argmax voting must not depend on class order:
    relabeling classes through any permutation permutes the predicted
    labels through the same map."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    X, y = mdata
    base = train_ovr(X, y, C=1.0, sigma2=4.0, eps=1e-3,
                     heuristic="multi5pc", chunk_iters=64, min_buffer=64)
    pred0 = base.predict(X)

    @given(perm=st.permutations(range(3)))
    @settings(max_examples=5, deadline=None)
    def check(perm):
        p = np.asarray(perm)
        mdl = train_ovr(X, p[y], C=1.0, sigma2=4.0, eps=1e-3,
                        heuristic="multi5pc", chunk_iters=64,
                        min_buffer=64)
        assert np.array_equal(mdl.predict(X), p[pred0])

    check()
