"""Unit coverage for ``launch/elastic.py`` — the StragglerWatchdog and
checkpoint-mediated rescale, both previously untested.

The watchdog tests drive a fake clock through the start/end protocol so
warmup gating, the bounded median window, threshold events and the
callback contract are asserted deterministically (no sleeps). The
rescale tests round-trip a pytree through ``ckpt.save`` -> ``rescale``
and check that restore targets the CURRENT process topology — the same
code path the SVM chaos tests exercise end-to-end through the driver.
"""
import os

import numpy as np
import pytest

from repro.ckpt import checkpoint as ck
from repro.launch import elastic


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


@pytest.fixture()
def clock(monkeypatch):
    c = FakeClock()
    monkeypatch.setattr(elastic.time, "perf_counter", c)
    return c


def _step(wd, clock, dt):
    wd.start_step()
    clock.t += dt
    return wd.end_step()


# ---------------------------------------------------------- watchdog --
def test_watchdog_warmup_suppresses_events(clock):
    wd = elastic.StragglerWatchdog(threshold=2.0, warmup=3)
    # the first `warmup` steps can be arbitrarily slow without an event —
    # there is no baseline to compare against yet
    assert _step(wd, clock, 100.0) is False
    assert _step(wd, clock, 100.0) is False
    assert _step(wd, clock, 100.0) is False
    assert wd.events == []
    # armed now: the window median is 100, so 150 is NOT a straggle ...
    assert _step(wd, clock, 150.0) is False
    # ... but > 2x the median is
    assert _step(wd, clock, 201.0) is True
    assert len(wd.events) == 1


def test_watchdog_threshold_and_median(clock):
    wd = elastic.StragglerWatchdog(threshold=3.0, warmup=3)
    for _ in range(5):
        assert _step(wd, clock, 1.0) is False
    assert _step(wd, clock, 2.9) is False     # below 3x median(=1.0)
    assert _step(wd, clock, 3.1) is True      # above
    step, dt, med = wd.events[-1]
    assert step == 7
    assert dt == pytest.approx(3.1)
    assert med == pytest.approx(1.0)


def test_watchdog_straggler_excluded_from_window(clock):
    # a flagged step must NOT poison the running median — otherwise one
    # straggle raises the baseline and masks the next one
    wd = elastic.StragglerWatchdog(threshold=2.0, warmup=3)
    for _ in range(4):
        _step(wd, clock, 1.0)
    assert _step(wd, clock, 10.0) is True
    assert 10.0 not in wd._times
    # median still 1.0 -> the same outlier fires again
    assert _step(wd, clock, 10.0) is True
    assert len(wd.events) == 2


def test_watchdog_window_is_bounded(clock):
    wd = elastic.StragglerWatchdog(threshold=3.0, window=8, warmup=3)
    for i in range(50):
        _step(wd, clock, 1.0 + 0.001 * i)
    assert len(wd._times) == 8
    # the window slid: only the newest 8 samples remain
    assert min(wd._times) == pytest.approx(1.0 + 0.001 * 42)


def test_watchdog_callback(clock):
    seen = []
    wd = elastic.StragglerWatchdog(
        threshold=2.0, warmup=3,
        on_straggle=lambda step, dt, med: seen.append((step, dt, med)))
    for _ in range(3):
        _step(wd, clock, 1.0)
    _step(wd, clock, 5.0)
    assert len(seen) == 1
    step, dt, med = seen[0]
    assert step == 4 and dt == pytest.approx(5.0) \
        and med == pytest.approx(1.0)


def test_watchdog_requires_start(clock):
    wd = elastic.StragglerWatchdog()
    with pytest.raises(AssertionError):
        wd.end_step()


# ----------------------------------------------------------- rescale --
def _tree(seed):
    rng = np.random.default_rng(seed)
    return {"w": rng.standard_normal((16, 8)).astype(np.float32),
            "b": rng.standard_normal((8,)).astype(np.float32)}


def test_rescale_round_trip(tmp_path):
    base = str(tmp_path)
    params = _tree(0)
    ck.save(os.path.join(base, "step_7"), 7, {"params": params})
    like = {"w": np.zeros((16, 8), np.float32),
            "b": np.zeros((8,), np.float32)}
    out, step = elastic.rescale(base, {"params": like}, {})
    assert step == 7
    for k in params:
        np.testing.assert_array_equal(np.asarray(out["params"][k]),
                                      params[k])


def test_rescale_picks_newest_complete_step(tmp_path):
    base = str(tmp_path)
    old, new = _tree(1), _tree(2)
    ck.save(os.path.join(base, "step_3"), 3, {"params": old})
    ck.save(os.path.join(base, "step_9"), 9, {"params": new})
    like = {k: np.zeros_like(v) for k, v in old.items()}
    out, step = elastic.rescale(base, {"params": like}, {})
    assert step == 9
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]), new["w"])
    # explicit step: restore the older generation
    out3, step3 = elastic.rescale(base, {"params": like}, {}, step=3)
    assert step3 == 3
    np.testing.assert_array_equal(np.asarray(out3["params"]["w"]),
                                  old["w"])


def test_rescale_no_checkpoints_raises(tmp_path):
    like = {"w": np.zeros((2, 2), np.float32)}
    with pytest.raises(FileNotFoundError):
        elastic.rescale(str(tmp_path / "empty"), {"params": like}, {})
