"""Hypothesis property tests on the system's invariants."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

pytest.importorskip("hypothesis")  # keep `pytest -x` green without the dep
from hypothesis import given, settings, strategies as st

from repro.core import smo
from repro.core.kernel_fns import full_kernel_matrix, rbf_rows2
from repro.data import sparse as sp

_f = st.floats(-10.0, 10.0, allow_nan=False, width=32)


@settings(max_examples=200, deadline=None)
@given(a_up=st.floats(0, 4), a_low=st.floats(0, 4),
       y_up=st.sampled_from([-1.0, 1.0]), y_low=st.sampled_from([-1.0, 1.0]),
       g_up=_f, g_low=_f, k_ul=st.floats(-1.0, 1.0))
def test_pair_update_preserves_constraints(a_up, a_low, y_up, y_low,
                                           g_up, g_low, k_ul):
    """Eq. 11 + joint clipping: both alphas stay in the box and
    sum(alpha*y) is exactly preserved (the dual equality constraint)."""
    C = 4.0
    au, al = smo.pair_update(
        jnp.float32(a_up), jnp.float32(a_low), jnp.float32(y_up),
        jnp.float32(y_low), jnp.float32(g_up), jnp.float32(g_low),
        jnp.float32(k_ul), jnp.float32(1.0), jnp.float32(1.0), C)
    au, al = float(au), float(al)
    assert -1e-5 <= au <= C + 1e-5
    assert -1e-5 <= al <= C + 1e-5
    before = a_up * y_up + a_low * y_low
    after = au * y_up + al * y_low
    assert abs(before - after) < 1e-4


@settings(max_examples=50, deadline=None)
@given(n=st.integers(4, 64), d=st.integers(1, 20), seed=st.integers(0, 99))
def test_rbf_kernel_bounds_and_symmetry(n, d, seed):
    r = np.random.default_rng(seed)
    X = jnp.asarray(r.normal(size=(n, d)).astype(np.float32))
    K = np.asarray(full_kernel_matrix("rbf", X, X, 0.25))
    assert (K > 0).all() and (K <= 1.0 + 1e-6).all()
    assert np.allclose(K, K.T, atol=1e-5)
    assert np.allclose(np.diag(K), 1.0, atol=1e-5)


@settings(max_examples=40, deadline=None)
@given(n=st.integers(2, 40), d=st.integers(2, 30), seed=st.integers(0, 99),
       density=st.floats(0.05, 0.9))
def test_sparse_roundtrip(n, d, seed, density):
    r = np.random.default_rng(seed)
    X = r.normal(size=(n, d)).astype(np.float32)
    X[r.random((n, d)) > density] = 0.0
    assert np.allclose(sp.to_csr(X).to_dense(), X)
    ell = sp.to_ell(X, lane=8)
    assert np.allclose(ell.to_dense(), X)
    assert np.allclose(ell.sq_norms(), (X * X).sum(1), rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 999))
def test_shrink_rule_only_drops_bound_samples(seed):
    """Eq. 10 never eliminates a free (0 < alpha < C) sample."""
    r = np.random.default_rng(seed)
    n, C = 64, 4.0
    alpha = r.choice([0.0, C, 1.3], size=n).astype(np.float32)
    y = r.choice([-1.0, 1.0], size=n).astype(np.float32)
    gamma = jnp.asarray(r.normal(size=n).astype(np.float32))
    active = jnp.ones(n, bool)
    new = smo.shrink_rule(gamma, jnp.asarray(alpha), jnp.asarray(y), active,
                          jnp.float32(-0.1), jnp.float32(0.1), C)
    dropped = ~np.asarray(new)
    free = (alpha > 0) & (alpha < C)
    assert not (dropped & free).any()


@settings(max_examples=20, deadline=None)
@given(b=st.integers(1, 3), lq=st.sampled_from([16, 32, 64]),
       h=st.sampled_from([2, 4]), hkv=st.sampled_from([1, 2]),
       seed=st.integers(0, 99))
def test_blockwise_attention_matches_ref(b, lq, h, hkv, seed):
    from repro.models import common
    from repro.kernels import ref
    if h % hkv:
        hkv = 1
    r = np.random.default_rng(seed)
    q = jnp.asarray(r.normal(size=(b, lq, h, 16)).astype(np.float32))
    k = jnp.asarray(r.normal(size=(b, lq, hkv, 16)).astype(np.float32))
    v = jnp.asarray(r.normal(size=(b, lq, hkv, 16)).astype(np.float32))
    out_b = common.blockwise_attention(q, k, v, block_q=8)
    out_r = ref.mha(q, k, v, causal=True)
    assert np.allclose(out_b, out_r, atol=2e-5), \
        np.abs(np.asarray(out_b - out_r)).max()


@settings(max_examples=20, deadline=None)
@given(n=st.sampled_from([128, 256]), d=st.integers(3, 40),
       seed=st.integers(0, 99))
def test_pallas_rows_match_oracle_property(n, d, seed):
    from repro.kernels import ops, ref
    r = np.random.default_rng(seed)
    X = jnp.asarray(r.normal(size=(n, d)).astype(np.float32))
    sq = jnp.sum(X * X, axis=-1)
    z2 = jnp.asarray(r.normal(size=(2, d)).astype(np.float32))
    got = ops.kernel_rows2("rbf", X, sq, z2, jnp.float32(0.125))
    want = ref.kernel_rows2(X, sq, z2, jnp.float32(0.125))
    assert np.allclose(got, want, atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(b=st.integers(1, 4), l=st.integers(2, 32), v=st.integers(4, 64),
       seed=st.integers(0, 99))
def test_cross_entropy_matches_naive(b, l, v, seed):
    from repro.models import common
    r = np.random.default_rng(seed)
    logits = jnp.asarray(r.normal(size=(b, l, v)).astype(np.float32))
    tgt = jnp.asarray(r.integers(0, v, size=(b, l)), dtype=jnp.int32)
    loss, aux = common.cross_entropy(logits, tgt, z_loss=0.0)
    lp = jax.nn.log_softmax(np.asarray(logits), axis=-1)
    want = -np.take_along_axis(np.asarray(lp), np.asarray(tgt)[..., None],
                               axis=-1).mean()
    assert abs(float(loss) - float(want)) < 1e-4


@settings(max_examples=10, deadline=None)
@given(chunk=st.sampled_from([4, 8, 16, 32]), seed=st.integers(0, 50))
def test_mlstm_chunk_size_invariance(chunk, seed):
    """The chunkwise mLSTM is exact: any chunk size gives the same output
    (the per-chunk stabilizers cancel algebraically)."""
    from repro.models.xlstm import _mlstm_chunk_scan
    r = np.random.default_rng(seed)
    L, dh = 32, 8
    q, k, v = (jnp.asarray(r.normal(size=(L, dh)).astype(np.float32))
               for _ in range(3))
    ig = jnp.asarray(r.normal(size=(L,)).astype(np.float32))
    lf = jnp.asarray(-np.abs(r.normal(size=(L,))).astype(np.float32))
    ref = _mlstm_chunk_scan(q, k, v, ig, lf, chunk=L)   # single chunk
    got = _mlstm_chunk_scan(q, k, v, ig, lf, chunk=chunk)
    assert np.allclose(got, ref, rtol=1e-4, atol=1e-4), \
        np.abs(np.asarray(got - ref)).max()


@settings(max_examples=10, deadline=None)
@given(chunk=st.sampled_from([4, 8, 16, 32]), seed=st.integers(0, 50))
def test_ssd_chunk_size_invariance(chunk, seed):
    """Mamba2/SSD chunkwise scan is exact for any chunk size."""
    from repro.models.zamba import _ssd_chunk_scan
    r = np.random.default_rng(seed)
    L, P, N = 32, 8, 4
    xdt = jnp.asarray(r.normal(size=(L, P)).astype(np.float32))
    B_ = jnp.asarray(r.normal(size=(L, N)).astype(np.float32))
    C_ = jnp.asarray(r.normal(size=(L, N)).astype(np.float32))
    la = jnp.asarray(-np.abs(r.normal(scale=0.2, size=(L,)))
                     .astype(np.float32))
    ref = _ssd_chunk_scan(xdt, B_, C_, la, chunk=L)
    got = _ssd_chunk_scan(xdt, B_, C_, la, chunk=chunk)
    assert np.allclose(got, ref, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 99), cap_f=st.floats(0.3, 2.0))
def test_moe_scatter_equals_einsum_dispatch(seed, cap_f):
    """The two MoE dispatch implementations are the same function,
    including under capacity overflow (dropped tokens)."""
    import dataclasses
    from repro import configs
    from repro.models.api import build
    cfg = dataclasses.replace(configs.smoke_config("phi3.5-moe-42b-a6.6b"),
                              capacity_factor=cap_f)
    model = build(cfg)
    params = model.init(cfg, jax.random.PRNGKey(seed))
    r = np.random.default_rng(seed)
    toks = jnp.asarray(r.integers(0, cfg.vocab_size, (2, 32)),
                       dtype=jnp.int32)
    le, _ = model.forward(params, dataclasses.replace(cfg, moe_impl="einsum"),
                          {"tokens": toks})
    ls, _ = model.forward(params, dataclasses.replace(cfg,
                                                      moe_impl="scatter"),
                          {"tokens": toks})
    assert np.allclose(le, ls, atol=5e-3), np.abs(np.asarray(le - ls)).max()
