"""SMO solver vs an independent QP oracle + KKT checks (paper Alg. 1/3/5)."""
import numpy as np
import pytest
import jax.numpy as jnp
from scipy import optimize

from repro.core import SVMConfig, SMOSolver, TABLE3, train
from repro.core.kernel_fns import full_kernel_matrix
from conftest import make_blobs


def _qp_oracle(X, y, C, s2, kernel="rbf"):
    n = len(y)
    K = np.asarray(full_kernel_matrix(
        kernel, jnp.asarray(X), jnp.asarray(X), 1 / (2 * s2))).astype(float)
    Q = (y[:, None] * y[None, :]) * K
    res = optimize.minimize(
        lambda a: -(a.sum() - 0.5 * a @ Q @ a),
        np.zeros(n), jac=lambda a: -(np.ones(n) - Q @ a),
        bounds=[(0, C)] * n,
        constraints=[{"type": "eq", "fun": lambda a: a @ y,
                      "jac": lambda a: y}],
        method="SLSQP", options={"maxiter": 800, "ftol": 1e-12})
    return -res.fun


@pytest.mark.parametrize("kernel,C,s2", [("rbf", 2.0, 1.5),
                                         ("rbf", 8.0, 4.0),
                                         ("linear", 1.0, 1.0)])
def test_dual_objective_matches_qp(kernel, C, s2):
    X, y = make_blobs(n=70, d=3, sep=0.8, seed=3)
    ref = _qp_oracle(X, y, C, s2, kernel)
    m = train(X, y, C=C, kernel=kernel, sigma2=s2, eps=1e-4)
    assert m.stats.converged
    assert abs(m.dual_objective() - ref) / abs(ref) < 5e-4


def test_kkt_conditions_hold():
    X, y = make_blobs(n=300, d=5, seed=1)
    C = 4.0
    m = train(X, y, C=C, sigma2=4.0, eps=1e-3)
    a = m.alpha
    # box + equality constraints (Eq. 2) — equality is exact by construction
    assert (a >= -1e-6).all() and (a <= C + 1e-6).all()
    assert abs(float((a * y).sum())) < 1e-4
    # beta_up + 2eps >= beta_low over ALL samples with exact gamma (Eq. 9)
    K = np.asarray(full_kernel_matrix(
        "rbf", jnp.asarray(X), jnp.asarray(X), 1 / 8.0))
    gamma = K @ (a * y) - y
    pos, at0, atc = y > 0, a <= 1e-7, a >= C - 1e-7
    i0 = ~at0 & ~atc
    b_up = gamma[i0 | (pos & at0) | (~pos & atc)].min()
    b_low = gamma[i0 | (pos & atc) | (~pos & at0)].max()
    assert b_up + 2 * 1e-3 >= b_low - 5e-5


@pytest.mark.parametrize("heuristic", sorted(TABLE3))
def test_all_heuristics_reach_same_solution(heuristic):
    """Shrinking is an optimization, not an approximation: every Table-3
    heuristic must land on the same dual objective as Original."""
    X, y = make_blobs(n=240, d=4, sep=0.9, seed=2)
    base = train(X, y, C=4.0, sigma2=2.0, eps=1e-3, heuristic="original")
    m = train(X, y, C=4.0, sigma2=2.0, eps=1e-3, heuristic=heuristic,
              chunk_iters=128)
    assert m.stats.converged
    rel = abs(m.dual_objective() - base.dual_objective()) \
        / abs(base.dual_objective())
    assert rel < 2e-3, (heuristic, rel)
    agree = (m.predict(X) == base.predict(X)).mean()
    assert agree > 0.995


def test_shrinking_actually_shrinks_and_reconstructs():
    X, y = make_blobs(n=800, d=6, sep=1.5, seed=5)
    m = train(X, y, C=4.0, sigma2=4.0, heuristic="multi5pc", chunk_iters=64)
    assert m.stats.shrink_events > 0
    assert m.stats.reconstructions >= 1
    assert m.stats.min_active < 800      # samples were eliminated
    assert m.stats.converged


def test_compaction_triggers_on_large_problem():
    X, y = make_blobs(n=3000, d=5, sep=2.0, seed=6)
    m = train(X, y, C=2.0, sigma2=2.0, heuristic="single5pc",
              chunk_iters=128, min_buffer=128)
    assert m.stats.compactions >= 1
    assert m.stats.converged
    # buffer shrank below the initial bucket
    assert min(m.stats.buffer_sizes) < max(m.stats.buffer_sizes)


def test_checkpoint_restart_resumes_to_same_solution(tmp_path):
    X, y = make_blobs(n=500, d=5, seed=7)
    kw = dict(C=4.0, sigma2=4.0, heuristic="multi5pc", chunk_iters=64)
    m0 = SMOSolver(SVMConfig(**kw)).fit(X, y)
    SMOSolver(SVMConfig(**kw, max_iters=128,
                        checkpoint_dir=str(tmp_path))).fit(X, y)
    m2 = SMOSolver(SVMConfig(**kw, checkpoint_dir=str(tmp_path),
                             resume=True)).fit(X, y)
    assert abs(m0.dual_objective() - m2.dual_objective()) \
        / m0.dual_objective() < 1e-3


def test_pallas_path_equals_jnp_path():
    X, y = make_blobs(n=512, d=6, seed=8)
    m1 = train(X, y, C=4.0, sigma2=4.0, heuristic="single1000")
    m2 = train(X, y, C=4.0, sigma2=4.0, heuristic="single1000",
               use_pallas=True)
    assert m1.stats.iterations == m2.stats.iterations
    assert abs(m1.dual_objective() - m2.dual_objective()) < 1e-2


def test_generalization_on_synthetic_benchmarks():
    from repro.data import make as make_ds
    X, y, Xt, yt = make_ds("a7a", scale=0.03, seed=0)
    m = train(X, y, C=32.0, sigma2=64.0, heuristic="multi10pc")
    acc = (m.predict(Xt) == yt).mean()
    assert acc > 0.70, acc   # noisy census-like data; paper gets ~0.84


def test_wss2_second_order_selection():
    """Paper's stated future work: second-order working-set selection
    converges to the same solution in fewer iterations."""
    X, y = make_blobs(n=500, d=6, sep=0.8, seed=9)
    m1 = train(X, y, C=4.0, sigma2=4.0, heuristic="multi10pc",
               selection="wss1")
    m2 = train(X, y, C=4.0, sigma2=4.0, heuristic="multi10pc",
               selection="wss2")
    assert m2.stats.converged
    assert m2.stats.iterations <= m1.stats.iterations
    assert abs(m1.dual_objective() - m2.dual_objective()) \
        / abs(m1.dual_objective()) < 5e-3
