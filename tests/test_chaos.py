"""Chaos suite: injected faults against the epoch cycle's recovery path.

What must hold (the ISSUE-10 acceptance contract):

  * a fit KILLED at any dispatch/save boundary and resumed — on the same
    device count or a different one — replays the uninterrupted
    trajectory: BITWISE on the same mesh (single host, and parallel on
    the saved device count — the membership mask rebuilds the exact
    buffer geometry), iterations-equal + objective-equal + ~1-ulp
    allclose alpha across device counts (different shard shapes compile
    a different executable; the PR-8 cross-executable contract);
  * a TRUNCATED or CORRUPTED checkpoint is detected (content checksums)
    and skipped, with resume falling back to the newest COMPLETE step
    instead of crashing or silently loading garbage;
  * a STRAGGLING dispatch trips the watchdog, which forces a checkpoint
    at that boundary without perturbing the trajectory.

The 4-device kill/rescale matrix runs in a subprocess with its own
XLA_FLAGS (the pattern of tests/test_distributed.py) so this process
keeps the default single device.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.ckpt import checkpoint as ck
from repro.core import MultiProblemDriver, SMOSolver, SVMConfig
from repro.data import make_sparse
from repro.launch import chaos

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
KW = dict(C=4.0, sigma2=4.0, chunk_iters=64, eps=1e-3,
          heuristic="multi5pc")


@pytest.fixture(scope="module")
def data():
    return make_sparse(600, 400, 0.04, seed=0)


@pytest.fixture(scope="module")
def full(data):
    X, y = data
    m = SMOSolver(SVMConfig(**KW)).fit(X, y)
    assert m.stats.converged
    return m


def _bitwise(a, b):
    assert np.array_equal(np.asarray(a).view(np.int32),
                          np.asarray(b).view(np.int32))


# ------------------------------------------------- kill + resume (1 dev) --
def test_kill_at_dispatch_resumes_bitwise(tmp_path, data, full):
    X, y = data
    d = str(tmp_path)
    cfg = SVMConfig(checkpoint_dir=d, checkpoint_every=2, **KW)
    kill = max(2, full.stats.dispatches // 2)
    with chaos.inject(chaos.FaultPlan(kill_at_dispatch=kill)) as plan:
        with pytest.raises(chaos.InjectedKill):
            SMOSolver(cfg).fit(X, y)
    assert plan.dispatches == kill + 1          # died AT the boundary
    assert ck.complete_steps(d), "no complete checkpoint before the kill"
    m2 = SMOSolver(dataclasses.replace(cfg, resume=True)).fit(X, y)
    assert m2.stats.resumed_from >= 0
    assert m2.stats.iterations == full.stats.iterations
    _bitwise(m2.alpha, full.alpha)
    assert m2.dual_objective() == full.dual_objective()


def test_kill_at_save_boundary_resumes_from_prior_save(tmp_path, data,
                                                       full):
    X, y = data
    d = str(tmp_path)
    cfg = SVMConfig(checkpoint_dir=d, checkpoint_every=2, **KW)
    # saves 0 and 1 complete; the fit dies entering save 2 — the save
    # boundary is a dispatch boundary, so nothing torn is left behind
    with chaos.inject(chaos.FaultPlan(kill_at_save=2)):
        with pytest.raises(chaos.InjectedKill):
            SMOSolver(cfg).fit(X, y)
    steps = ck.complete_steps(d)
    assert len(steps) == 2
    m2 = SMOSolver(dataclasses.replace(cfg, resume=True)).fit(X, y)
    assert m2.stats.resumed_from == steps[-1]
    assert m2.stats.iterations == full.stats.iterations
    _bitwise(m2.alpha, full.alpha)


# ------------------------------------------------- corruption fallback --
@pytest.mark.parametrize("mode", ["truncate", "flip", "manifest"])
def test_corrupt_newest_step_falls_back(tmp_path, data, full, mode):
    X, y = data
    d = str(tmp_path)
    cfg = SVMConfig(checkpoint_dir=d, checkpoint_every=1, **KW)
    cut = int(full.stats.iterations * 0.6)
    SMOSolver(dataclasses.replace(cfg, max_iters=cut)).fit(X, y)
    steps = ck.complete_steps(d)
    assert len(steps) >= 2
    chaos.corrupt_step(d, mode=mode)
    assert ck.complete_steps(d) == steps[:-1]
    m2 = SMOSolver(dataclasses.replace(cfg, resume=True)).fit(X, y)
    assert m2.stats.resumed_from == steps[-2]
    assert m2.stats.iterations == full.stats.iterations
    _bitwise(m2.alpha, full.alpha)


def test_config_mismatch_refused(tmp_path, data):
    X, y = data
    d = str(tmp_path)
    cfg = SVMConfig(checkpoint_dir=d, checkpoint_every=1, max_iters=128,
                    **KW)
    SMOSolver(cfg).fit(X, y)
    bad = dataclasses.replace(cfg, C=8.0, resume=True)
    with pytest.raises(ValueError, match="C"):
        SMOSolver(bad).fit(X, y)


def test_multi_corruption_falls_back_to_prev_generation(tmp_path, data):
    X, y = data
    Y = np.broadcast_to(y, (2, y.size)).copy()
    Cs = np.asarray([1.0, 4.0])
    kw = dict(KW, chunk_iters=64)
    full = MultiProblemDriver(SVMConfig(**kw)).fit_tasks(X, Y, C=Cs)
    cut = max(r["iterations"] for r in full[0].stats.per_problem) // 2
    d = str(tmp_path)
    MultiProblemDriver(SVMConfig(checkpoint_dir=d, max_iters=cut,
                                 **kw)).fit_tasks(X, Y, C=Cs)
    cur = os.path.join(d, "multi_masters.npz")
    prev = os.path.join(d, "multi_masters.prev.npz")
    assert os.path.exists(cur) and os.path.exists(prev)
    chaos.truncate_file(cur)
    with pytest.warns(UserWarning, match="corrupt"):
        m2 = MultiProblemDriver(
            SVMConfig(checkpoint_dir=d, resume=True,
                      **kw)).fit_tasks(X, Y, C=Cs)
    assert m2[0].stats.converged
    for k in range(2):
        np.testing.assert_allclose(m2[k].alpha, full[k].alpha, atol=1e-6)


# ------------------------------------------------------- watchdog wire --
def test_watchdog_forces_checkpoint_and_keeps_trajectory(tmp_path, data,
                                                         full):
    X, y = data
    d = str(tmp_path)
    # cadence would never save (every=10^6); only the watchdog's forced
    # save can produce a step dir. One injected 0.5 s delay is >> the
    # CPU dispatch median, so exactly that dispatch straggles.
    cfg = SVMConfig(checkpoint_dir=d, checkpoint_every=10**6,
                    watchdog_threshold=5.0, watchdog_warmup=3, **KW)
    with chaos.inject(chaos.FaultPlan(delay_dispatch=5,
                                      delay_seconds=0.5)):
        m = SMOSolver(cfg).fit(X, y)
    assert m.stats.straggle_events >= 1
    assert ck.complete_steps(d), "straggle did not force a checkpoint"
    # a delay changes wall time only — the trajectory is untouched
    assert m.stats.iterations == full.stats.iterations
    _bitwise(m.alpha, full.alpha)


# ------------------------------------------------ checkpoint layer unit --
def test_restore_detects_per_array_corruption(tmp_path):
    d = str(tmp_path / "step_1")
    arr = {"a": np.arange(32, dtype=np.float32)}
    ck.save(d, 1, {"g": arr})
    # rewrite the npz with one array tampered AND patch the file-level
    # sha to match, leaving the per-array checksums stale — only the
    # array-content check can catch this
    fn = os.path.join(d, "g.npz")
    with np.load(fn) as z:
        data = {k: np.array(z[k]) for k in z.files}
    data["a"][3] += 1.0
    np.savez(fn, **data)
    man = ck.load_manifest(d)
    man["groups"]["g"]["sha256"] = ck._sha(fn)
    import json
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump(man, f)
    with pytest.raises(IOError, match="content checksum"):
        ck.restore(d, "g", {"a": np.zeros(32, np.float32)})


def test_complete_steps_skips_torn_and_corrupt(tmp_path):
    base = str(tmp_path)
    for s in (1, 2, 3):
        ck.save(os.path.join(base, f"step_{s}"), s,
                {"g": {"a": np.full(8, float(s), np.float32)}})
    # torn: a dir with no manifest at all (crash before publish never
    # leaves this, but a partial copy might)
    os.makedirs(os.path.join(base, "step_4"))
    # corrupt: flip a byte of step_3's payload
    chaos.flip_byte(os.path.join(base, "step_3", "g.npz"))
    assert ck.complete_steps(base) == [1, 2]
    assert ck.latest_step(base) == 3     # manifest still parses ...
    assert not ck.step_complete(os.path.join(base, "step_3"))  # ... but
    # the content check fails, so resume walks back to step_2


def test_save_overwrite_replaces_atomically(tmp_path):
    d = str(tmp_path / "step_5")
    ck.save(d, 5, {"g": {"a": np.zeros(4, np.float32)}})
    ck.save(d, 5, {"g": {"a": np.ones(4, np.float32)}})
    assert ck.step_complete(d)
    out = ck.restore(d, "g", {"a": np.zeros(4, np.float32)})
    np.testing.assert_array_equal(np.asarray(out["a"]), np.ones(4))
    # no stray temp dirs left in the parent
    assert sorted(os.listdir(tmp_path)) == ["step_5"]


def test_with_retries_bounded_backoff():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("transient")
        return "ok"

    out, retries = ck.with_retries(flaky, attempts=3, backoff=0.001)
    assert out == "ok" and retries == 2

    def dead():
        raise OSError("gone")

    with pytest.raises(IOError, match="failed after 2"):
        ck.with_retries(dead, attempts=2, backoff=0.001)

    def corrupt():
        raise ValueError("not transient")

    calls["n"] = 0
    with pytest.raises(ValueError):
        ck.with_retries(corrupt, attempts=5, backoff=0.001)


def test_parse_spec():
    p = chaos.parse_spec("kill@3")
    assert p.kill_at_dispatch == 3 and p.kill_at_save is None
    p = chaos.parse_spec("kill-save@2")
    assert p.kill_at_save == 2
    p = chaos.parse_spec("delay@5:0.25")
    assert p.delay_dispatch == 5 and p.delay_seconds == 0.25 \
        and not p.delay_every
    p = chaos.parse_spec("delay-all@1:0.1")
    assert p.delay_every
    with pytest.raises(ValueError):
        chaos.parse_spec("explode@1")
    with pytest.raises(ValueError):
        chaos.parse_spec("kill")


# --------------------------------------- 4-dev kill -> rescale matrix --
def test_kill_mid_schedule_resume_on_1_2_4_devices():
    """THE tentpole acceptance test: a 4-device fit is killed
    mid-schedule and resumed on 1, 2, and 4 devices; every resume must
    replay the uninterrupted run — same iteration count, same final
    alpha/objective. Resuming on the SAME mesh is bitwise (the saved
    membership mask rebuilds the killed run's exact buffer geometry, so
    it is the same executable); a DIFFERENT device count changes shard
    shapes, so XLA compiles a different program and the dense GEMM
    partitioning drifts by ulps — iterations and objective still match,
    alpha to ~1-ulp allclose (the PR-8 cross-executable contract).

    Each resume target restores from its OWN copy of the post-kill
    checkpoint dir: resumed fits write checkpoints too, and sharing one
    dir would make later targets resume from an earlier target's saves
    instead of the kill point."""
    code = """
        import dataclasses, os, shutil, numpy as np
        from repro.core import SVMConfig
        from repro.core.parallel import ParallelSMOSolver
        from repro.ckpt import checkpoint as ck
        from repro.data import make_sparse
        from repro.launch import chaos
        X, y = make_sparse(600, 400, 0.04, seed=0)
        kw = dict(C=4.0, sigma2=4.0, heuristic='multi5pc',
                  chunk_iters=64, eps=1e-3)
        for fmt in ('dense', 'ell'):
            ref = ParallelSMOSolver(SVMConfig(format=fmt, **kw),
                                    devices=4).fit(X, y)
            assert ref.stats.converged, fmt
            kill = max(2, ref.stats.dispatches // 2)
            snap = os.path.join('{tmp}', fmt)
            cfg = SVMConfig(format=fmt, checkpoint_dir=snap,
                            checkpoint_every=2, **kw)
            try:
                with chaos.inject(chaos.FaultPlan(kill_at_dispatch=kill)):
                    ParallelSMOSolver(cfg, devices=4).fit(X, y)
                raise SystemExit('kill did not fire: ' + fmt)
            except chaos.InjectedKill:
                pass
            steps = ck.complete_steps(snap)
            assert steps and steps[-1] < ref.stats.iterations, \\
                'kill landed after convergence: ' + fmt
            for m in (1, 2, 4):
                d = snap + '_m%d' % m
                shutil.copytree(snap, d)
                got = ParallelSMOSolver(
                    dataclasses.replace(cfg, checkpoint_dir=d,
                                        resume=True),
                    devices=m).fit(X, y)
                assert got.stats.resumed_from == steps[-1], (fmt, m)
                assert got.stats.iterations == ref.stats.iterations, \\
                    (fmt, m)
                if m == 4:
                    # same mesh -> same buffer geometry -> bitwise
                    assert np.array_equal(
                        got.alpha.view(np.int32),
                        ref.alpha.view(np.int32)), (fmt, m)
                assert np.allclose(got.alpha, ref.alpha,
                                   atol=1e-5), (fmt, m)
                ro = ref.dual_objective()
                assert abs(got.dual_objective() - ro) <= 1e-4 * (
                    1.0 + abs(ro)), (fmt, m)
        print('CHAOS_RESCALE_OK')
    """
    import tempfile
    with tempfile.TemporaryDirectory() as tmp:
        env = dict(os.environ,
                   XLA_FLAGS="--xla_force_host_platform_device_count=4",
                   PYTHONPATH=os.path.join(ROOT, "src"))
        out = subprocess.run(
            [sys.executable, "-c",
             textwrap.dedent(code).replace("{tmp}", tmp)],
            capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "CHAOS_RESCALE_OK" in out.stdout
