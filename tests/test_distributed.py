"""Multi-device tests — each spawns a subprocess with its own XLA_FLAGS so
the main pytest process keeps the default single device."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_parallel_smo_equals_sequential_8dev():
    out = run_sub("""
        import numpy as np, json
        from repro.core import SVMConfig, train
        from repro.core.parallel import ParallelSMOSolver
        rng = np.random.default_rng(0)
        n = 800
        X = np.vstack([rng.normal(+0.9, 1, (n//2, 8)),
                       rng.normal(-0.9, 1, (n//2, 8))]).astype(np.float32)
        y = np.concatenate([np.ones(n//2), -np.ones(n//2)]).astype(np.float32)
        seq = train(X, y, C=4.0, sigma2=4.0, heuristic='original')
        res = {}
        for h in ['original', 'single1000', 'multi5pc']:
            m = ParallelSMOSolver(SVMConfig(C=4.0, sigma2=4.0, heuristic=h,
                                            chunk_iters=128)).fit(X, y)
            res[h] = (m.stats.iterations, m.dual_objective(),
                      m.stats.converged)
        res['seq'] = (seq.stats.iterations, seq.dual_objective(), True)
        print(json.dumps(res))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    ref_obj = res["seq"][1]
    assert res["original"][0] == res["seq"][0]     # identical trajectory
    for h in ("original", "single1000", "multi5pc"):
        assert res[h][2], h
        assert abs(res[h][1] - ref_obj) / abs(ref_obj) < 2e-3, (h, res)


def test_ring_reconstruction_matches_host_8dev():
    out = run_sub("""
        import numpy as np
        from repro.core import SVMConfig, dataplane
        from repro.core.parallel import ParallelSMOSolver
        from repro.core.reconstruct import reconstruct_gamma
        rng = np.random.default_rng(1)
        n, d = 640, 10
        X = rng.normal(size=(n, d)).astype(np.float32)
        y = rng.choice([-1.0, 1.0], n).astype(np.float32)
        alpha = (rng.random(n) * (rng.random(n) < 0.3)).astype(np.float32)
        stale = np.flatnonzero(rng.random(n) < 0.5)
        s = ParallelSMOSolver(SVMConfig(sigma2=2.0))
        s._store = dataplane.DenseStore(X)
        ring = s._reconstruct(y, alpha, stale)
        host = reconstruct_gamma('rbf', X, y, alpha, stale, 0.25)
        err = np.abs(ring - host).max()
        assert err < 1e-3, err
        print('RINGOK', err)
    """)
    assert "RINGOK" in out


def test_sharded_train_step_and_elastic_restore_8dev(tmp_path):
    out = run_sub(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro import configs
        from repro.models.api import build
        from repro.launch import train_lib
        from repro.optim import adamw
        from repro.ckpt import checkpoint as ckpt

        cfg = configs.smoke_config('llama3-8b')
        model = build(cfg)
        bnp = {{'tokens': np.zeros((8, 32), np.int32),
               'targets': np.zeros((8, 32), np.int32)}}
        b = jax.tree.map(jnp.asarray, bnp)

        def run(mesh_shape, axes, steps, restore_dir=None):
            from repro.launch.mesh import make_mesh
            mesh = make_mesh(mesh_shape, axes,
                devices=jax.devices()[:int(np.prod(mesh_shape))])
            psh, osh, bsh, (pshp, oshp) = train_lib.shardings_for(cfg, mesh, b)
            from repro.launch import mesh as meshlib
            with meshlib.set_mesh(mesh):
                if restore_dir:
                    params = ckpt.restore(restore_dir, 'params', pshp, psh)
                    opt = ckpt.restore(restore_dir, 'opt', oshp, osh)
                else:
                    params = jax.jit(lambda k: model.init(cfg, k),
                                     out_shardings=psh)(jax.random.PRNGKey(0))
                    opt = jax.jit(adamw.init, out_shardings=osh)(params)
                step = train_lib.make_train_step(cfg, adamw.AdamWConfig(lr=1e-3), mesh)
                js = jax.jit(step, in_shardings=(psh, osh, bsh),
                             out_shardings=(psh, osh, None))
                for _ in range(steps):
                    params, opt, metrics = js(params, opt,
                                              jax.device_put(b, bsh))
            return params, opt, float(metrics['loss'])

        # 4x2 mesh for 3 steps, checkpoint, then elastic-restore on 2x4
        p, o, loss1 = run((4, 2), ('data', 'model'), 3)
        d = r'{tmp_path}/step_3'
        ckpt.save(d, 3, {{'params': p, 'opt': o}})
        p2, o2, loss2 = run((2, 4), ('data', 'model'), 1, restore_dir=d)
        # continuous reference: 4 steps straight
        p3, o3, loss3 = run((4, 2), ('data', 'model'), 4)
        print('LOSSES', loss2, loss3)
        assert abs(loss2 - loss3) < 1e-4, (loss2, loss3)
        print('ELASTICOK')
    """)
    assert "ELASTICOK" in out


def test_grad_compression_multipod_4dev():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import configs
        from repro.models.api import build
        from repro.launch import train_lib
        from repro.optim import adamw

        cfg = configs.smoke_config('llama3-8b')
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 2, 1), ('pod', 'data', 'model'))
        bnp = {'tokens': np.zeros((8, 32), np.int32),
               'targets': np.zeros((8, 32), np.int32)}
        b = jax.tree.map(jnp.asarray, bnp)
        model = build(cfg)
        psh, osh, bsh, (pshp, oshp) = train_lib.shardings_for(cfg, mesh, b)
        from repro.launch import mesh as meshlib
        with meshlib.set_mesh(mesh):
            params = jax.jit(lambda k: model.init(cfg, k),
                             out_shardings=psh)(jax.random.PRNGKey(0))
            opt = jax.jit(adamw.init, out_shardings=osh)(params)
            losses = {}
            for method in (None, 'bf16', 'int8'):
                step = train_lib.make_train_step(
                    cfg, adamw.AdamWConfig(lr=1e-3), mesh,
                    grad_compress=method)
                out = step(params, opt, jax.device_put(b, bsh), None)
                if method:   # second step reuses error-feedback residuals
                    out = step(params, opt, jax.device_put(b, bsh), out[3])
                losses[str(method)] = float(out[2]['loss'])
        base = losses['None']
        assert abs(losses['bf16'] - base) < 1e-2, losses
        assert abs(losses['int8'] - base) < 5e-2, losses
        print('COMPRESSOK', losses)
    """, devices=4)
    assert "COMPRESSOK" in out


def test_parallel_multi_batched_equals_single_host_4dev():
    """Batched K-problem training under shard_map (one stacked all_gather
    election per joint iteration, owner-shard alpha writes) vs the
    single-host batched driver, per problem, through full convergence
    including reconstruction + un-shrink.

    Dense is BITWISE: every fp-producing subgraph is either a sealed
    barrier island or an order-pinned unrolled chain, and the dense
    islands compile identically in the shard-local and full-buffer
    executables. ELL is iterations-equal + allclose only: the O(d)
    row islands over ELL-scattered rows were observed to come out one
    ulp apart between the two executables (the fusion pass can split a
    sealed island's three d-length reductions differently per module,
    which changes the contraction), so the cross-EXECUTABLE bit
    guarantee cannot be made for ELL on this backend. Within one
    executable the trajectory is deterministic — reruns are identical."""
    out = run_sub("""
        import numpy as np
        from repro.core import MultiProblemDriver, SVMConfig
        rng = np.random.default_rng(7)
        n, d = 384, 24
        X = rng.normal(size=(n, d)).astype(np.float32)
        X[rng.random(X.shape) < 0.5] = 0.0
        w = rng.normal(size=d)
        s = X @ w + 0.4 * rng.normal(size=n)
        y = np.where(s > np.median(s), 1.0, -1.0).astype(np.float32)
        Cs = np.geomspace(0.5, 8.0, 4)
        Y = np.broadcast_to(y, (4, n)).copy()
        kw = dict(C=1.0, sigma2=4.0, eps=1e-3, heuristic="multi5pc",
                  chunk_iters=64, fuse_iters=4, min_buffer=64,
                  selection="wss1")
        for fmt in ("dense", "ell"):
            cfg = SVMConfig(format=fmt, **kw)
            ms = MultiProblemDriver(cfg).fit_tasks(X, Y, C=Cs)
            mp = MultiProblemDriver(cfg, parallel=True).fit_tasks(
                X, Y, C=Cs)
            ss, sp = ms[0].stats, mp[0].stats
            for k in range(4):
                assert (ss.per_problem[k]["iterations"]
                        == sp.per_problem[k]["iterations"]), (fmt, k)
                if fmt == "dense":
                    assert np.array_equal(ms[k].alpha, mp[k].alpha), k
                else:
                    assert np.allclose(ms[k].alpha, mp[k].alpha,
                                       atol=1e-5), (fmt, k)
                    ro = ms[k].dual_objective()
                    assert abs(mp[k].dual_objective() - ro) < 1e-4 * (
                        1.0 + abs(ro)), (fmt, k)
            assert ss.converged and sp.converged
        print("MULTIPAROK")
    """, devices=4)
    assert "MULTIPAROK" in out
