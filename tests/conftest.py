# NOTE: deliberately does NOT set --xla_force_host_platform_device_count —
# smoke tests and benches must see 1 device (dryrun.py owns the 512-device
# flag). Multi-device tests spawn subprocesses with their own XLA_FLAGS.
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_blobs(n=400, d=5, sep=1.0, seed=0):
    r = np.random.default_rng(seed)
    X = np.vstack([r.normal(+sep, 1.0, (n // 2, d)),
                   r.normal(-sep, 1.0, (n - n // 2, d))]).astype(np.float32)
    y = np.concatenate([np.ones(n // 2),
                        -np.ones(n - n // 2)]).astype(np.float32)
    p = r.permutation(n)
    return X[p], y[p]
