"""Inference-plane tests: ``core.serve.ServeEngine`` vs the host scoring
oracle (``SVMModel.decision_function_host``) across storage formats,
backends, sharding, query ingest formats, bucket padding, and the
``compact()`` deployment artifact."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import ServeEngine, SVMConfig, SMOSolver
from repro.data import sparse as sp

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _problem(fmt, n=300, d=6, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d)).astype(np.float32)
    y = np.where(X[:, 0] + 0.3 * X[:, 1] > 0, 1.0, -1.0).astype(np.float32)
    m = SMOSolver(SVMConfig(C=1.0, sigma2=1.0, format=fmt)).fit(X, y)
    Z = (X[rng.integers(0, n, 137)] +
         0.1 * rng.normal(size=(137, d))).astype(np.float32)
    return m, Z


def _to_csr(Z):
    indptr = np.zeros(Z.shape[0] + 1, np.int64)
    data, idx = [], []
    for i, row in enumerate(Z):
        nz = np.flatnonzero(row)
        data.append(row[nz])
        idx.append(nz)
        indptr[i + 1] = indptr[i] + nz.size
    return sp.CSRMatrix(np.concatenate(data).astype(np.float32),
                        np.concatenate(idx).astype(np.int32), indptr, Z.shape)


@pytest.mark.parametrize("fmt", ["dense", "ell"])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_engine_matches_host_oracle(fmt, use_pallas):
    m, Z = _problem(fmt)
    ref = m.decision_function_host(Z)
    assert np.abs(ref).max() > 0.5          # scores are O(1), not all -beta
    eng = ServeEngine(m, use_pallas=use_pallas)
    np.testing.assert_allclose(eng.decision_function(Z), ref,
                               rtol=1e-4, atol=2e-5)
    assert eng.describe()["n_sv"] == m.sv_coef.size


@pytest.mark.parametrize("fmt", ["dense", "ell"])
def test_model_routes_through_engine(fmt):
    """``decision_function``/``predict`` go through the cached engine and
    agree with the host oracle; the engine cache is per keyword spec."""
    m, Z = _problem(fmt)
    ref = m.decision_function_host(Z)
    np.testing.assert_allclose(m.decision_function(Z), ref,
                               rtol=1e-4, atol=2e-5)
    np.testing.assert_array_equal(m.predict(Z),
                                  np.where(ref >= 0.0, 1.0, -1.0))
    assert m.serve_engine() is m.serve_engine()
    assert m.serve_engine() is not m.serve_engine(min_bucket=32)


@pytest.mark.parametrize("fmt", ["dense", "ell"])
def test_csr_query_ingest_exact(fmt):
    """CSR queries densify per bucket on the host — scores must be
    bit-identical to scoring the same matrix passed dense."""
    m, Z = _problem(fmt)
    Zs = Z.copy()
    Zs[np.abs(Zs) < 0.5] = 0.0
    eng = ServeEngine(m)
    np.testing.assert_array_equal(eng.decision_function(_to_csr(Zs)),
                                  eng.decision_function(Zs))


@pytest.mark.parametrize("fmt", ["dense", "ell"])
def test_bucket_padding_invariance(fmt):
    """A query's score must not depend on which pow2 bucket it rides in:
    per-row kernel math never mixes rows, so one-at-a-time scoring, the
    whole batch, and a ragged split must agree to float tolerance."""
    m, Z = _problem(fmt)
    eng = ServeEngine(m, min_bucket=16, max_bucket=64)
    whole = eng.decision_function(Z)              # 64-buckets + ragged tail
    one = np.concatenate([eng.decision_function(Z[i: i + 1])
                          for i in range(0, 24)])
    np.testing.assert_allclose(whole[:24], one, rtol=1e-5, atol=1e-6)
    split = np.concatenate([eng.decision_function(Z[:50]),
                            eng.decision_function(Z[50:])])
    np.testing.assert_allclose(whole, split, rtol=1e-5, atol=1e-6)
    assert set(eng.describe()["buckets"]) <= {16, 32, 64}


@pytest.mark.parametrize("fmt", ["dense", "ell"])
def test_compact_scores_identical_fp32(fmt):
    """Dedup (coefs merge over bitwise-equal rows) + zero-coef pruning is
    score-exact in fp32; constructed duplicates shrink the SV set."""
    m, Z = _problem(fmt)
    ref = m.decision_function_host(Z)
    # graft duplicates: repeat the first 40 SV rows with split coefs
    if fmt == "dense":
        m2 = type(m)(m.config, np.concatenate([m.sv_x, m.sv_x[:40]]),
                     np.concatenate([m.sv_coef, 0 * m.sv_coef[:40]]),
                     m.beta, m.alpha, m.stats)
    else:
        m2 = type(m)(m.config, None,
                     np.concatenate([m.sv_coef, 0 * m.sv_coef[:40]]),
                     m.beta, m.alpha, m.stats,
                     sv_vals=np.concatenate([m.sv_vals, m.sv_vals[:40]]),
                     sv_cols=np.concatenate([m.sv_cols, m.sv_cols[:40]]),
                     n_features=m.n_features)
    mc = m2.compact()
    assert mc.sv_coef.size <= m.sv_coef.size       # dupes + zero coefs gone
    assert np.all(mc.sv_coef != 0.0)
    np.testing.assert_allclose(mc.decision_function(Z), ref,
                               rtol=1e-4, atol=2e-5)
    np.testing.assert_allclose(mc.decision_function_host(Z), ref,
                               rtol=1e-4, atol=2e-5)


def test_compact_merges_split_coefs():
    """Duplicated rows with nonzero split coefficients merge additively."""
    m, Z = _problem("dense")
    half = (m.sv_coef / 2).astype(np.float32)
    m2 = type(m)(m.config, np.concatenate([m.sv_x, m.sv_x]),
                 np.concatenate([half, half]), m.beta, m.alpha, m.stats)
    mc = m2.compact()
    assert mc.sv_coef.size == m.sv_coef.size
    np.testing.assert_allclose(mc.decision_function(Z),
                               m.decision_function_host(Z),
                               rtol=1e-4, atol=2e-5)


@pytest.mark.parametrize("fmt", ["dense", "ell"])
def test_bf16_storage_tolerance(fmt):
    """bf16 SV storage: half the resident value bytes, scores within the
    one-storage-rounding envelope of fp32."""
    m, Z = _problem(fmt)
    ref = m.decision_function_host(Z)
    e32 = ServeEngine(m)
    e16 = ServeEngine(m, dtype="bfloat16")
    assert e16.describe()["dtype"] == "bfloat16"
    got = e16.decision_function(Z)
    np.testing.assert_allclose(got, ref, rtol=2e-2, atol=3e-2)
    # value arrays are exactly half the bytes
    assert np.asarray(e16._sv[0]).nbytes * 2 == np.asarray(e32._sv[0]).nbytes
    # compact(dtype=...) round-trips through the model API too
    mb = m.compact(dtype="bfloat16")
    np.testing.assert_allclose(mb.decision_function(Z), ref,
                               rtol=2e-2, atol=3e-2)
    np.testing.assert_allclose(mb.decision_function_host(Z), ref,
                               rtol=2e-2, atol=3e-2)


def test_engine_rejects_bad_specs():
    m, Z = _problem("dense")
    with pytest.raises(ValueError):
        ServeEngine(m, dtype="float16")
    with pytest.raises(ValueError):
        ServeEngine(m, min_bucket=0)
    with pytest.raises(ValueError):
        ServeEngine(m).decision_function(Z[:, :3])   # wrong feature dim


def test_sharded_engine_matches_single_device_4dev():
    """shard_map engine on 4 forced host devices == single-device scores
    (psum over SV partials; fp32, so only reduction-order noise)."""
    code = """
        import numpy as np
        from repro.core import ServeEngine, SVMConfig, SMOSolver
        rng = np.random.default_rng(0)
        n, d = 300, 6
        X = rng.normal(size=(n, d)).astype(np.float32)
        y = np.where(X[:, 0] + 0.3 * X[:, 1] > 0, 1.0,
                     -1.0).astype(np.float32)
        Z = (X[rng.integers(0, n, 137)] +
             0.1 * rng.normal(size=(137, d))).astype(np.float32)
        for fmt in ("dense", "ell"):
            m = SMOSolver(SVMConfig(C=1.0, sigma2=1.0, format=fmt)).fit(X, y)
            ref = m.decision_function_host(Z)
            for up in (False, True):
                eng = ServeEngine(m, shards=4, use_pallas=up)
                assert eng.describe()["shards"] == 4
                np.testing.assert_allclose(eng.decision_function(Z), ref,
                                           rtol=1e-4, atol=2e-5)
        print("OK")
    """
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


def test_roofline_pricing_terms():
    """Bucket-executable pricing: positive compute/memory terms and a
    useful_ratio in (0, 1] against the model FLOPs."""
    m, _ = _problem("dense")
    rf = ServeEngine(m).roofline(64).row()
    assert rf["t_compute_s"] > 0 and rf["t_memory_s"] > 0
    assert 0 < rf["useful_ratio"] <= 1.5      # CPU HLO may fuse below model
