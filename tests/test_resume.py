"""Checkpoint save -> resume round-trips and shrink accounting.

Covers the two resume-time accounting fixes that rode in with the adaptive-K
data plane: (1) a Single-policy checkpoint taken after its one
reconstruction carries ``shrink_on=False`` and must NOT re-enable shrinking
on resume (the chunk runner used to be built before the restore, with the
heuristic's interval baked in); (2) ``shrink_events`` is cumulative in the
solver state and must not be re-accumulated once per outer pass (it grew
quadratically with reconstructions under the Multi policy).
"""
import os

import numpy as np
import pytest

from repro.ckpt import checkpoint as ck
from repro.core import SMOSolver, SVMConfig, train
from repro.data import make_sparse

KW = dict(C=4.0, sigma2=4.0, chunk_iters=64, eps=1e-3)


@pytest.fixture(scope="module")
def data():
    return make_sparse(600, 400, 0.04, seed=0)


def _full(X, y, heur, fmt):
    return train(X, y, heuristic=heur, format=fmt, **KW)


@pytest.mark.parametrize("heur,fmt", [("single5pc", "dense"),
                                      ("multi5pc", "dense"),
                                      ("multi5pc", "ell")])
def test_resume_matches_uninterrupted(tmp_path, data, heur, fmt):
    X, y = data
    full = _full(X, y, heur, fmt)
    assert full.stats.converged
    cut = int(full.stats.iterations * 0.6)
    d = str(tmp_path)
    m1 = SMOSolver(SVMConfig(heuristic=heur, format=fmt, checkpoint_dir=d,
                             max_iters=cut, **KW)).fit(X, y)
    assert m1.stats.iterations <= cut < full.stats.iterations
    m2 = SMOSolver(SVMConfig(heuristic=heur, format=fmt, checkpoint_dir=d,
                             resume=True, **KW)).fit(X, y)
    assert m2.stats.converged
    assert m2.stats.iterations == full.stats.iterations
    np.testing.assert_allclose(m2.alpha, full.alpha, atol=1e-6)
    rel = abs(m2.dual_objective() - full.dual_objective()) \
        / abs(full.dual_objective())
    assert rel < 1e-3, rel


def test_single_policy_resume_keeps_shrinking_off(tmp_path, data):
    """Interrupt AFTER the Single policy's one reconstruction: the restored
    ``shrink_on=False`` must rebuild the runner with interval=0."""
    X, y = data
    full = _full(X, y, "single5pc", "dense")
    assert full.stats.reconstructions == 1
    m1 = None
    d = str(tmp_path)
    # walk the cut back from the end until it lands in the re-optimize tail
    # (reconstruction already done, convergence not yet reached)
    for back in (20, 60, 120, 250):
        cut = full.stats.iterations - back
        m1 = SMOSolver(SVMConfig(heuristic="single5pc", checkpoint_dir=d,
                                 max_iters=cut, **KW)).fit(X, y)
        if m1.stats.reconstructions >= 1 and not m1.stats.converged:
            break
    assert m1.stats.reconstructions >= 1, "cut landed before reconstruction"
    m2 = SMOSolver(SVMConfig(heuristic="single5pc", checkpoint_dir=d,
                             resume=True, **KW)).fit(X, y)
    assert m2.stats.converged
    # shrinking stayed off: no new shrink events past the restored count
    assert m2.stats.shrink_events == full.stats.shrink_events
    assert m2.stats.iterations == full.stats.iterations
    np.testing.assert_allclose(m2.alpha, full.alpha, atol=1e-6)


def test_multi_shrink_events_counted_once(tmp_path, data):
    """Multi policy with several reconstructions: reported shrink_events is
    the cumulative solver count, not a per-pass re-accumulation (which grew
    ~(R+1)/2-fold with R reconstructions)."""
    X, y = data
    d = str(tmp_path)
    m = SMOSolver(SVMConfig(heuristic="multi5pc", checkpoint_dir=d,
                            **KW)).fit(X, y)
    assert m.stats.reconstructions >= 2          # several outer passes ran
    assert m.stats.shrink_events > 0
    # the final checkpoint is written after the last chunk of the last
    # pass, so its meta holds the true cumulative count
    step = ck.latest_step(d)
    man = ck.load_manifest(os.path.join(d, f"step_{step}"))
    assert m.stats.shrink_events == man["extra"]["shrink_events"]
