"""Data pipeline, checkpoint, sharding-rule, optimizer and heuristic units."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import heuristics as H
from repro.ckpt import checkpoint as ckpt
from repro.data import (SPECS, TokenPipeline, csr_space_report, density,
                        make)
from repro.optim import adamw, compress


# ------------------------------------------------------------------- data
def test_generators_match_spec_statistics():
    for name in ("a7a", "w7a", "usps", "mushrooms", "ijcnn"):
        spec = SPECS[name]
        X, y, Xt, yt = make(name, scale=0.05, seed=0)
        assert X.shape[1] == spec.d
        assert set(np.unique(y)) <= {-1.0, 1.0}
        bal = (y > 0).mean()
        assert 0.25 < bal < 0.75, (name, bal)
        if spec.kind == "sparse_binary":
            assert abs(density(X) - spec.density) < 0.05, name


def test_csr_space_report_matches_fig1b_shape():
    X, _, _, _ = make("w7a", scale=0.03, seed=1)
    rep = csr_space_report(X)
    assert rep["csr_saving_pct"] > 80        # ~4% dense -> big CSR saving
    X2, _, _, _ = make("ijcnn", scale=0.01, seed=1)
    rep2 = csr_space_report(X2)
    assert rep2["csr_saving_pct"] < 0        # dense data: CSR costs more


def test_token_pipeline_deterministic_skip_ahead():
    tp = TokenPipeline(1000, batch=4, seq_len=32, seed=9)
    a = tp.batch_at(100)
    b = tp.batch_at(100)
    assert (a["tokens"] == b["tokens"]).all()
    assert (a["targets"][:, :-1] == a["tokens"][:, 1:]).all()
    sh = tp.shard_for(100, host_id=1, n_hosts=2)
    assert (sh["tokens"] == a["tokens"][2:4]).all()


# ------------------------------------------------------------------- ckpt
def test_checkpoint_roundtrip_and_corruption(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
    d = str(tmp_path / "step_1")
    ckpt.save(d, 1, {"params": tree})
    back = ckpt.restore(d, "params", tree)
    assert jax.tree.all(jax.tree.map(
        lambda x, y: bool(jnp.allclose(x.astype(jnp.float32),
                                       y.astype(jnp.float32))), tree, back))
    # corrupt and expect detection
    import glob
    fn = glob.glob(f"{d}/params.npz")[0]
    with open(fn, "r+b") as f:
        f.seek(100)
        f.write(b"\x00" * 8)
    with pytest.raises(IOError):
        ckpt.restore(d, "params", tree)


def test_latest_step_scan(tmp_path):
    assert ckpt.latest_step(str(tmp_path)) is None
    for s in (1, 5, 3):
        ckpt.save(str(tmp_path / f"step_{s}"), s, {"g": {"x": jnp.zeros(2)}})
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_async_save(tmp_path):
    t = ckpt.save(str(tmp_path / "step_2"), 2,
                  {"g": {"x": jnp.ones(4)}}, async_=True)
    t.join(timeout=30)
    assert ckpt.load_manifest(str(tmp_path / "step_2"))["step"] == 2


# --------------------------------------------------------------- sharding
def test_param_rules_divisibility_fallbacks():
    from repro.launch import sharding as shd
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    # shapes modeled on yi-34b: 56 heads don't divide 16; hd=128 does.
    spec = shd._spec_for("layers.wq", (60, 7168, 56, 128), _mesh16(),
                         shd._PARAM_RULES, ("data",))
    assert spec == P(None, "data", None, "model")   # falls back to head_dim
    spec2 = shd._spec_for("layers.wq", (32, 4096, 32, 128), _mesh16(),
                          shd._PARAM_RULES, ("data",))
    assert spec2 == P(None, "data", "model", None)  # heads divide
    spec3 = shd._spec_for("layers.we_gate", (32, 16, 4096, 6400),
                          _mesh16(), shd._PARAM_RULES, ("data",))
    assert spec3 == P(None, "model", "data", None)  # expert parallel


def _mesh16():
    """A fake 16x16 mesh view for rule checks (no devices needed)."""
    class M:
        axis_names = ("data", "model")

        class devices:
            shape = (16, 16)
    return M


def test_batch_specs_nondivisible_replicates():
    from repro.launch import sharding as shd
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("data",))
    sds = {"tokens": jax.ShapeDtypeStruct((1, 8), jnp.int32)}
    specs = shd.batch_specs(sds, mesh)
    assert specs["tokens"] == P(("data",), None)


# -------------------------------------------------------------- optimizer
def test_adamw_descends_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                            decay_steps=1000, grad_clip=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw.init(params)
    for _ in range(200):
        g = {"w": 2 * params["w"]}
        params, opt, _ = adamw.update(cfg, g, opt, params)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_int8_quantization_error_feedback():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(1000,))
                    .astype(np.float32))
    q, s = compress.quantize_int8(x)
    deq = compress.dequantize_int8(q, s)
    rel = float(jnp.abs(deq - x).max() / jnp.abs(x).max())
    assert rel < 0.01  # 1/127 scale granularity


# -------------------------------------------------------------- heuristics
def test_table3_heuristics_complete():
    assert len(H.TABLE3) == 13  # original + 12 shrinking rows
    assert H.get("multi5pc").policy == "multi"
    assert H.get("single2").interval(10000) == 2
    assert H.get("multi50pc").interval(10000) == 5000
    assert H.get("original").interval(10000) == 0
    with pytest.raises(ValueError):
        H.get("nope")


# ---------------------------------------------------------------- elastic
def test_elastic_rescale_and_watchdog(tmp_path):
    import time as _time
    import jax.numpy as jnp
    from repro.launch import elastic

    tree = {"w": jnp.arange(8, dtype=jnp.float32)}
    ckpt.save(str(tmp_path / "step_7"), 7, {"params": tree})
    out, step = elastic.rescale(str(tmp_path), {"params": tree},
                                {"params": None})
    assert step == 7
    assert bool(jnp.allclose(out["params"]["w"], tree["w"]))

    hits = []
    wd = elastic.StragglerWatchdog(threshold=3.0, warmup=2,
                                   on_straggle=lambda s, dt, med:
                                   hits.append(s))
    for i in range(4):
        wd.start_step()
        _time.sleep(0.01)
        wd.end_step()
    wd.start_step()
    _time.sleep(0.2)                      # 20x the median -> straggle
    assert wd.end_step()
    assert hits
