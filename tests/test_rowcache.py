"""Kernel-row LRU cache + row-provider layer: unit semantics, the
cache-on == cache-off bitwise exactness contract (single-host and
multi-device, dense and ELL, wss1 and wss2), the invalidation-by-remap
lifecycle, cache-aware FLOP accounting, and the Table 3 heuristic grid
running against the cached hot loop."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import SMOSolver, SVMConfig, TABLE3, rowcache, train
from repro.data import make_sparse
from test_distributed import run_sub


def _blobs(n=400, d=6, sep=0.9, seed=0):
    rng = np.random.default_rng(seed)
    X = np.vstack([rng.normal(+sep, 1, (n // 2, d)),
                   rng.normal(-sep, 1, (n // 2, d))]).astype(np.float32)
    y = np.concatenate([np.ones(n // 2), -np.ones(n // 2)]).astype(np.float32)
    return X, y


# ------------------------------------------------------------------ unit ops
def test_get_pair_hit_miss_and_lru():
    c = rowcache.init_cache(4, 8)
    mk = lambda a, b: jnp.asarray([a, b], jnp.int32)
    rows_ab = jnp.stack([jnp.full((8,), 1.0), jnp.full((8,), 2.0)], axis=1)
    rows, c = rowcache.get_pair(c, mk(10, 11), lambda: rows_ab)
    assert (int(c.hits), int(c.misses)) == (0, 2)
    np.testing.assert_array_equal(rows, rows_ab)
    # same pair again: served from the table, no compute
    boom = lambda: jnp.full((8, 2), np.nan)
    rows, c = rowcache.get_pair(c, mk(10, 11), boom)
    assert (int(c.hits), int(c.misses)) == (2, 2)
    np.testing.assert_array_equal(rows, rows_ab)
    # rows inserted by different pairs can pair-hit together
    rows2 = jnp.stack([jnp.full((8,), 3.0), jnp.full((8,), 4.0)], axis=1)
    _, c = rowcache.get_pair(c, mk(12, 13), lambda: rows2)
    got, c = rowcache.get_pair(c, mk(11, 12), boom)
    assert int(c.hits) == 4
    np.testing.assert_array_equal(got[:, 0], rows_ab[:, 1])
    np.testing.assert_array_equal(got[:, 1], rows2[:, 0])
    # table is full: a fresh pair evicts the two least-recently-used slots
    # (10 and 13; 11/12 were just touched)
    rows3 = jnp.stack([jnp.full((8,), 5.0), jnp.full((8,), 6.0)], axis=1)
    _, c = rowcache.get_pair(c, mk(20, 21), lambda: rows3)
    assert set(np.asarray(c.tags).tolist()) == {11, 12, 20, 21}


def test_get_row_single_and_duplicate_gid():
    c = rowcache.init_cache(2, 4)
    r1 = jnp.full((4,), 7.0)
    row, c = rowcache.get_row(c, jnp.int32(5), lambda: r1)
    assert (int(c.hits), int(c.misses)) == (0, 1)
    row, c = rowcache.get_row(c, jnp.int32(5), lambda: jnp.full((4,), np.nan))
    assert (int(c.hits), int(c.misses)) == (1, 1)
    np.testing.assert_array_equal(row, r1)
    # duplicate gids in one pair collapse onto one slot, not two
    c = rowcache.init_cache(4, 4)
    dup = jnp.stack([r1, r1], axis=1)
    _, c = rowcache.get_pair(c, jnp.asarray([9, 9], jnp.int32), lambda: dup)
    assert int(np.sum(np.asarray(c.tags) == 9)) == 1


def test_remap_shrink_regathers_grow_invalidates():
    c = rowcache.init_cache(3, 6)
    vals = np.arange(18, dtype=np.float32).reshape(3, 6)
    c = c._replace(tags=jnp.asarray([4, 9, -1], jnp.int32),
                   vals=jnp.asarray(vals), hits=jnp.int32(5),
                   misses=jnp.int32(7))
    old_idx = np.array([2, 4, 7, 9, -1, -1])
    new_idx = np.array([4, 9, -1, -1])       # compaction: subset survives
    r = rowcache.remap_cache(c, old_idx, new_idx)
    np.testing.assert_array_equal(r.tags, [4, 9, -1])   # entries survive
    np.testing.assert_array_equal(np.asarray(r.vals)[:, :2], vals[:, [1, 3]])
    np.testing.assert_array_equal(np.asarray(r.vals)[:, 2:], 0.0)
    assert (int(r.hits), int(r.misses)) == (5, 7)       # counters carry over
    # un-shrink: re-added rows have no cached columns -> wholesale drop
    g = rowcache.remap_cache(c, old_idx, np.array([2, 4, 5, 7, 9, -1]))
    assert (np.asarray(g.tags) == -1).all()
    assert (int(g.hits), int(g.misses)) == (5, 7)
    assert rowcache.remap_cache(None, old_idx, new_idx) is None


# ------------------------------------------------- exactness (the core test)
@pytest.mark.parametrize("fmt", ["dense", "ell"])
def test_cache_exactness_through_compaction_and_reconstruction(fmt):
    """Cache-on must be bit-identical to cache-off: same iteration count,
    bitwise-equal alpha — across physical compactions (cache remap) and
    reconstruction un-shrinks (cache invalidation)."""
    X, y = make_sparse(900, 300, 0.05, seed=3, noise=0.05, label_noise=0.0,
                       margin=0.5)
    kw = dict(C=2.0, sigma2=40.0, heuristic="multi5pc", chunk_iters=64,
              min_buffer=64, format=fmt)
    m0 = train(X, y, **kw)
    m1 = train(X, y, row_cache=True, **kw)
    assert m0.stats.compactions >= 1          # remap path exercised
    assert m0.stats.reconstructions >= 1      # invalidate path exercised
    assert m1.stats.iterations == m0.stats.iterations
    np.testing.assert_array_equal(m1.alpha, m0.alpha)
    assert m1.stats.cache_hits + m1.stats.cache_misses > 0
    assert m0.stats.cache_hits == 0           # off really is off


def test_cache_exactness_wss2():
    X, y = _blobs(n=500, d=6, sep=0.8, seed=9)
    kw = dict(C=4.0, sigma2=4.0, heuristic="multi10pc", selection="wss2")
    m0 = train(X, y, **kw)
    m1 = train(X, y, row_cache=True, **kw)
    assert m1.stats.iterations == m0.stats.iterations
    np.testing.assert_array_equal(m1.alpha, m0.alpha)
    assert m1.stats.cache_hit_rate > 0


def test_cache_hits_and_flops_discount():
    X, y = _blobs()
    kw = dict(C=4.0, sigma2=4.0, heuristic="multi5pc", chunk_iters=64)
    m0 = train(X, y, **kw)
    m1 = train(X, y, row_cache=True, **kw)
    # every iteration looks up exactly one row pair
    assert m1.stats.cache_hits + m1.stats.cache_misses \
        == 2 * m1.stats.iterations
    assert m1.stats.cache_hit_rate > 0.3      # repeat-heavy tail
    assert 0 < m1.stats.flops_est < m0.stats.flops_est   # hits are discounted
    assert m1.stats.cache_hit_rate == pytest.approx(
        m1.stats.cache_hits / (m1.stats.cache_hits + m1.stats.cache_misses))


def test_flops_est_selection_aware():
    """wss2 bills the extra second-order selection sweep (satellite of the
    provider refactor): more FLOPs/iter than wss1 on the same buffer."""
    X, y = _blobs(n=300, d=5, seed=4)
    kw = dict(C=4.0, sigma2=4.0, heuristic="original")
    m1 = train(X, y, selection="wss1", **kw)
    m2 = train(X, y, selection="wss2", **kw)
    assert m1.stats.flops_est / m1.stats.iterations \
        < m2.stats.flops_est / m2.stats.iterations


def test_slot_bucketing():
    assert rowcache.bucket_slots(1) == 2
    assert rowcache.bucket_slots(64) == 64
    assert rowcache.bucket_slots(65) == 128
    s = SMOSolver(SVMConfig(row_cache=True, row_cache_slots=100))
    assert s._cache_slots() == 128
    assert SMOSolver(SVMConfig(row_cache=False))._cache_slots() == 0


# ------------------------------------------------------------- multi-device
def test_parallel_cache_exactness_and_wss2_4dev():
    out = run_sub("""
        import numpy as np, json
        from repro.core import SVMConfig, train
        from repro.core.parallel import ParallelSMOSolver
        from repro.data import make_sparse
        X, y = make_sparse(640, 400, 0.04, seed=0)
        kw = dict(C=4.0, sigma2=4.0, heuristic='multi5pc', chunk_iters=64)
        res = {}
        for fmt in ('dense', 'ell'):
            m0 = ParallelSMOSolver(SVMConfig(format=fmt, **kw)).fit(X, y)
            m1 = ParallelSMOSolver(SVMConfig(format=fmt, row_cache=True,
                                             **kw)).fit(X, y)
            res[fmt] = dict(
                iters=[m0.stats.iterations, m1.stats.iterations],
                alpha_eq=bool(np.array_equal(m0.alpha, m1.alpha)),
                looked=m1.stats.cache_hits + m1.stats.cache_misses,
                conv=bool(m1.stats.converged))
        # wss2 threading through the parallel runner (regression: it used
        # to be silently ignored) + cache exactness on top of it
        seq = train(X, y, selection='wss2', **kw)
        p0 = ParallelSMOSolver(SVMConfig(selection='wss2', **kw)).fit(X, y)
        p1 = ParallelSMOSolver(SVMConfig(selection='wss2', row_cache=True,
                                         **kw)).fit(X, y)
        res['wss2'] = dict(
            iters=[seq.stats.iterations, p0.stats.iterations,
                   p1.stats.iterations],
            obj=[seq.dual_objective(), p0.dual_objective()],
            conv=bool(p0.stats.converged),
            alpha_eq=bool(np.array_equal(p0.alpha, p1.alpha)))
        print(json.dumps(res))
    """, devices=4)
    import json
    res = json.loads(out.strip().splitlines()[-1])
    for fmt in ("dense", "ell"):
        r = res[fmt]
        assert r["conv"], r
        assert r["iters"][0] == r["iters"][1], r    # identical trajectory
        assert r["alpha_eq"], r                     # bitwise
        assert r["looked"] == 2 * r["iters"][1], r  # one pair lookup/iter
    w = res["wss2"]
    assert w["conv"], w
    # parallel wss2 == sequential wss2 (not wss1-with-no-warning)
    assert w["iters"][0] == w["iters"][1], w
    assert abs(w["obj"][1] - w["obj"][0]) / abs(w["obj"][0]) < 1e-6, w
    assert w["alpha_eq"], w                         # cache exact under wss2


# ------------------------------------------------ Table 3 grid, cached loop
@pytest.fixture(scope="module")
def grid_baseline():
    X, y = _blobs(n=200, d=4, sep=1.0, seed=7)
    base = train(X, y, C=4.0, sigma2=2.0, eps=1e-3, heuristic="original")
    return X, y, base.dual_objective()


@pytest.mark.parametrize("heuristic", sorted(TABLE3))
def test_table3_grid_converges_with_cache(grid_baseline, heuristic):
    """Every Table 3 entry (Single/Multi x random/numsamples, all
    aggressiveness classes) must converge to the Original baseline's dual
    objective with the cached hot loop — shrinking and caching are both
    optimizations, never approximations."""
    X, y, ref = grid_baseline
    m = train(X, y, C=4.0, sigma2=2.0, eps=1e-3, heuristic=heuristic,
              chunk_iters=64, row_cache=True, row_cache_slots=32)
    assert m.stats.converged
    assert abs(m.dual_objective() - ref) / abs(ref) < 2e-3, heuristic
