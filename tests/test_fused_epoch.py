"""Fused multi-iteration device epochs (``SVMConfig.fuse_iters``).

The contract under test: any ``fuse_iters`` k produces the bit-identical
trajectory of the k=1 oracle — same iteration count, same shrink events,
same reconstructions, same alpha BITS — because all schedule scalars are
traced (one XLA executable per buffer geometry, for every k) and the
host's legacy per-chunk decisions (convergence, compaction trigger,
checkpoint cadence) are replayed exactly on device between segments.
Satellites ride along: the segment/checkpoint alignment rule
(``heuristics.fuse_budget``), the device twins of the host scheduling
arithmetic (``util.bucket_pow2_device``, ``dataplane.ell_shard_extents_dyn``),
and save -> resume landing mid-epoch-schedule.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import SMOSolver, SVMConfig
from repro.core import dataplane, heuristics, util
from repro.data import make_sparse

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# shrink-heavy config: converges ~1.3k iters with 2 compactions and >= 2
# reconstructions under multi5pc, so a fused run crosses every epoch-cycle
# boundary (shrink, compact, reconstruct, un-shrink) the driver has
SHRINKY = dict(C=2.0, sigma2=40.0, heuristic="multi5pc", chunk_iters=64,
               min_buffer=64, eps=1e-3)


@pytest.fixture(scope="module")
def data():
    return make_sparse(600, 300, 0.05, seed=3, noise=0.05, label_noise=0.0,
                       margin=0.5)


def _fit(X, y, k, **kw):
    return SMOSolver(SVMConfig(fuse_iters=k, **SHRINKY, **kw)).fit(X, y)


# ---------------------------------------------------------------- parity --
@pytest.mark.parametrize("fmt", ["dense", "ell"])
@pytest.mark.parametrize("cache", [False, True])
@pytest.mark.parametrize("sel", ["wss1", "wss2"])
def test_bitwise_parity_vs_k1_oracle(data, fmt, cache, sel):
    X, y = data
    kw = dict(format=fmt, selection=sel)
    if cache:
        kw.update(row_cache=True, row_cache_slots=128)
    oracle = _fit(X, y, 1, **kw)
    assert oracle.stats.converged
    assert oracle.stats.compactions >= 1          # the run exercises Alg. 5
    assert oracle.stats.reconstructions >= 1
    assert oracle.stats.dispatches >= oracle.stats.iterations \
        // SHRINKY["chunk_iters"]
    for k in (4, 32):
        m = _fit(X, y, k, **kw)
        assert m.stats.iterations == oracle.stats.iterations
        assert m.stats.shrink_events == oracle.stats.shrink_events
        assert m.stats.reconstructions == oracle.stats.reconstructions
        assert m.stats.compactions == oracle.stats.compactions
        assert m.stats.min_active == oracle.stats.min_active
        assert m.stats.cache_hits == oracle.stats.cache_hits
        assert np.array_equal(m.alpha.view(np.int32),
                              oracle.alpha.view(np.int32)), (fmt, cache, sel, k)
        assert m.beta == oracle.beta
        # the fusion actually fused: strictly fewer host round-trips
        assert m.stats.dispatches < oracle.stats.dispatches
        assert len(m.stats.dispatch_times) == m.stats.dispatches


def test_parallel_fused_parity_4dev(data):
    code = """
        import numpy as np
        from repro.core import SVMConfig
        from repro.core.parallel import ParallelSMOSolver
        from repro.data import make_sparse
        X, y = make_sparse(600, 300, 0.05, seed=3, noise=0.05,
                           label_noise=0.0, margin=0.5)
        kw = dict(C=2.0, sigma2=40.0, heuristic='multi5pc', chunk_iters=64,
                  min_buffer=64, eps=1e-3)
        for fmt in ('dense', 'ell'):
            m1 = ParallelSMOSolver(
                SVMConfig(fuse_iters=1, format=fmt, **kw)).fit(X, y)
            m8 = ParallelSMOSolver(
                SVMConfig(fuse_iters=8, format=fmt, **kw)).fit(X, y)
            assert m1.stats.compactions >= 1, fmt
            assert m8.stats.iterations == m1.stats.iterations, fmt
            assert m8.stats.shrink_events == m1.stats.shrink_events, fmt
            assert np.array_equal(m8.alpha.view(np.int32),
                                  m1.alpha.view(np.int32)), fmt
            assert m8.stats.dispatches < m1.stats.dispatches, fmt
        print('PARITY_OK')
    """
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "PARITY_OK" in out.stdout


# ------------------------------------------------------- checkpoint align --
def test_fused_checkpoint_schedule_matches_oracle(tmp_path, data):
    """A fused run must SAVE at exactly the oracle's iteration counts: the
    segment budget of each dispatch is clipped to the checkpoint cadence,
    never the other way around."""
    X, y = data
    d1, d8 = str(tmp_path / "k1"), str(tmp_path / "k8")
    os.makedirs(d1), os.makedirs(d8)
    m1 = SMOSolver(SVMConfig(fuse_iters=1, checkpoint_dir=d1,
                             checkpoint_every=4, **SHRINKY)).fit(X, y)
    m8 = SMOSolver(SVMConfig(fuse_iters=8, checkpoint_dir=d8,
                             checkpoint_every=4, **SHRINKY)).fit(X, y)
    assert m8.stats.iterations == m1.stats.iterations
    saves1 = sorted(os.listdir(d1))
    saves8 = sorted(os.listdir(d8))
    assert saves1 and saves1 == saves8


def test_fused_resume_lands_mid_schedule(tmp_path, data):
    """Interrupt a fuse_iters=8 run mid-epoch-schedule (max_iters lands
    inside a would-be-fused dispatch) and resume: the continuation must
    converge on the uninterrupted fused trajectory."""
    X, y = data
    full = _fit(X, y, 8)
    assert full.stats.converged
    d = str(tmp_path)
    cut = int(full.stats.iterations * 0.6)
    m1 = SMOSolver(SVMConfig(fuse_iters=8, checkpoint_dir=d,
                             checkpoint_every=3, max_iters=cut,
                             **SHRINKY)).fit(X, y)
    assert m1.stats.iterations <= cut < full.stats.iterations
    m2 = SMOSolver(SVMConfig(fuse_iters=8, checkpoint_dir=d,
                             checkpoint_every=3, resume=True,
                             **SHRINKY)).fit(X, y)
    assert m2.stats.converged
    assert m2.stats.iterations == full.stats.iterations
    assert m2.stats.shrink_events == full.stats.shrink_events
    np.testing.assert_allclose(m2.alpha, full.alpha, atol=1e-6)


def test_fuse_budget_never_skips_boundary():
    """Property test: for ANY (fuse_iters, cadence) the fused scheduler
    produces exactly the oracle's save points — no Alg. 5 checkpoint
    boundary ever falls strictly inside a dispatch."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=300, deadline=None)
    @given(fuse=st.integers(1, 64), every=st.integers(1, 16),
           ckpt=st.integers(0, 10_000), total=st.integers(1, 500))
    def prop(fuse, every, ckpt, total):
        b = heuristics.fuse_budget(fuse, ckpt, every)
        assert 1 <= b <= max(1, fuse)
        # no boundary strictly inside the dispatch...
        assert all((ckpt + s) % every != 0 for s in range(1, b))
        # ...and the budget is maximal: full k, or ends ON a boundary
        assert b == max(1, fuse) or (ckpt + b) % every == 0
        # end-to-end: replay a run of `total` segments; the fused save
        # points must equal the one-segment-per-dispatch oracle's
        done, saves = 0, []
        while done < total:
            segs = min(heuristics.fuse_budget(fuse, done, every),
                       total - done)         # run may hard-exit early
            done += segs
            if done % every == 0:
                saves.append(done)
        assert saves == [s for s in range(1, total + 1) if s % every == 0]

    prop()
    # cadence off -> uncapped
    assert heuristics.fuse_budget(7, 123, 0) == 7
    assert heuristics.fuse_budget(0, 0, 5) == 1


# ------------------------------------------------------------ device twins --
def test_bucket_pow2_device_matches_host():
    import jax.numpy as jnp
    ns = list(range(0, 300)) + [511, 512, 513, 1023, 1024, 1025,
                                (1 << 20) - 1, 1 << 20, (1 << 20) + 1]
    for lo in (1, 8, 24, 64):
        got = np.asarray(util.bucket_pow2_device(
            jnp.asarray(ns, jnp.int32), jnp.int32(lo)))
        want = np.array([util.bucket_pow2(n, lo) for n in ns], np.int32)
        np.testing.assert_array_equal(got, want, err_msg=f"lo={lo}")


def test_ell_shard_extents_dyn_matches_static():
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    for p in (1, 2, 4):
        for _ in range(6):
            m_per = int(rng.integers(4, 40))
            m, K = p * m_per, int(rng.integers(1, 9))
            vals = (rng.random((m, K)).astype(np.float32)
                    * (rng.random((m, K)) < 0.6))
            keep = rng.random(m) < 0.5
            n_act = jnp.int32(int(keep.sum()))
            want = np.asarray(dataplane.ell_shard_extents(
                jnp.asarray(vals), jnp.asarray(keep), n_act,
                p=p, m_per=m_per))
            got = np.asarray(dataplane.ell_shard_extents_dyn(
                jnp.asarray(vals), jnp.asarray(keep), n_act, p))
            np.testing.assert_array_equal(got, want, err_msg=f"p={p}")
