"""Per-kernel shape/dtype sweeps vs the ref.py oracles (interpret mode)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.rbf_row import rbf_rows2
from repro.kernels.gamma_update import gamma_update
from repro.kernels.sparse_ell import ell_kernel_row
from repro.kernels.flash_attention import flash_attention


@pytest.mark.parametrize("n,d,bm", [(256, 32, 128), (512, 100, 256),
                                    (1024, 784, 512), (2048, 123, 1024)])
def test_rbf_rows2_sweep(n, d, bm):
    r = np.random.default_rng(n + d)
    X = jnp.asarray(r.normal(size=(n, d)).astype(np.float32))
    sq = jnp.sum(X * X, axis=-1)
    z2 = jnp.asarray(r.normal(size=(2, d)).astype(np.float32))
    inv = jnp.float32(1 / 8)
    got = rbf_rows2(X, sq, z2, inv, block_m=bm, interpret=True).T
    want = ref.kernel_rows2(X, sq, z2, inv)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("n,d", [(256, 17), (512, 256), (1024, 64)])
def test_gamma_update_sweep(n, d):
    r = np.random.default_rng(n * d)
    X = jnp.asarray(r.normal(size=(n, d)).astype(np.float32))
    sq = jnp.sum(X * X, axis=-1)
    g = jnp.asarray(r.normal(size=(n,)).astype(np.float32))
    z2 = jnp.asarray(r.normal(size=(2, d)).astype(np.float32))
    c2 = jnp.asarray(r.normal(size=(2,)).astype(np.float32))
    inv = jnp.float32(0.3)
    got = gamma_update(X, sq, g, z2, c2, inv, block_m=256 if n % 256 == 0
                       else 128, interpret=True)
    want = ref.gamma_update(X, sq, g, z2, c2, inv)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,d,K", [(256, 100, 16), (512, 300, 64),
                                   (512, 123, 128)])
def test_ell_row_sweep(n, d, K):
    r = np.random.default_rng(n + K)
    cols = r.integers(0, d, size=(n, K)).astype(np.int32)
    vals = r.normal(size=(n, K)).astype(np.float32)
    # random padding tail
    for i in range(n):
        t = r.integers(0, K)
        vals[i, t:] = 0.0
        cols[i, t:] = 0
    sq = jnp.sum(jnp.asarray(vals) ** 2, axis=-1)
    z = jnp.asarray(r.normal(size=(d,)).astype(np.float32))
    inv = jnp.float32(0.2)
    got = ell_kernel_row(jnp.asarray(vals), jnp.asarray(cols), sq, z, inv,
                         block_m=128, interpret=True)
    want = ref.ell_kernel_row(jnp.asarray(vals), jnp.asarray(cols), sq, z,
                              inv)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("b,h,hkv,l,dh,dtype",
                         [(1, 4, 4, 128, 32, np.float32),
                          (2, 4, 2, 256, 64, np.float32),
                          (1, 8, 1, 256, 64, np.float32),
                          (2, 4, 2, 128, 64, jnp.bfloat16)])
def test_flash_attention_sweep(b, h, hkv, l, dh, dtype):
    r = np.random.default_rng(b * l + h)
    mk = lambda *s: jnp.asarray(r.normal(size=s).astype(np.float32)).astype(
        dtype)
    q = mk(b, h, l, dh)
    k = mk(b, hkv, l, dh)
    v = mk(b, hkv, l, dh)
    got = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                          interpret=True)
    want = ops._fa_ref(q, k, v, True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_noncausal():
    r = np.random.default_rng(0)
    q = jnp.asarray(r.normal(size=(1, 2, 128, 32)).astype(np.float32))
    k = jnp.asarray(r.normal(size=(1, 2, 128, 32)).astype(np.float32))
    v = jnp.asarray(r.normal(size=(1, 2, 128, 32)).astype(np.float32))
    got = flash_attention(q, k, v, causal=False, block_q=64, block_k=64,
                          interpret=True)
    want = ops._fa_ref(q, k, v, False)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_flash_attention_grad_path():
    """custom_vjp backward (oracle recompute) is differentiable."""
    r = np.random.default_rng(1)
    q = jnp.asarray(r.normal(size=(1, 2, 64, 16)).astype(np.float32))
    k = jnp.asarray(r.normal(size=(1, 1, 64, 16)).astype(np.float32))
    v = jnp.asarray(r.normal(size=(1, 1, 64, 16)).astype(np.float32))
    g = jax.grad(lambda q: ops.flash_attention(q, k, v, True).sum())(q)
    gr = jax.grad(lambda q: ops._fa_ref(q, k, v, True).sum())(q)
    np.testing.assert_allclose(g, gr, rtol=1e-4, atol=1e-4)


def test_ops_fallback_paths():
    """Non-RBF kernels and non-pow2 sizes fall back to the oracle."""
    r = np.random.default_rng(2)
    X = jnp.asarray(r.normal(size=(100, 7)).astype(np.float32))  # 100 % 128
    sq = jnp.sum(X * X, axis=-1)
    z2 = jnp.asarray(r.normal(size=(2, 7)).astype(np.float32))
    out = ops.kernel_rows2("rbf", X, sq, z2, jnp.float32(0.5))
    assert out.shape == (100, 2)
    out_lin = ops.kernel_rows2("linear", X, sq, z2, jnp.float32(0.5))
    np.testing.assert_allclose(out_lin, X @ z2.T, rtol=1e-5)


@pytest.mark.parametrize("m,d,b,bm,bq", [(256, 32, 64, 128, 64),
                                         (512, 100, 128, 256, 128),
                                         (1024, 40, 256, 512, 128)])
def test_rbf_accumulate_sweep(m, d, b, bm, bq):
    """Fused serve-time decision sum vs the materializing oracle, with
    coef-0 padding rows asserted to contribute exactly 0."""
    from repro.kernels.rbf_row import rbf_accumulate
    r = np.random.default_rng(m + b)
    X = r.normal(size=(m, d)).astype(np.float32)
    coef = r.normal(size=(m,)).astype(np.float32)
    X[-bm // 2:] = r.normal(size=(bm // 2, d)) * 100   # padding-row garbage
    coef[-bm // 2:] = 0.0                              # ... with coef 0
    Z = r.normal(size=(b, d)).astype(np.float32)
    sq = jnp.sum(jnp.asarray(X) ** 2, axis=-1)
    inv = jnp.float32(1 / 8)
    got = rbf_accumulate(jnp.asarray(X), sq, jnp.asarray(coef),
                         jnp.asarray(Z), inv, block_m=bm, block_q=bq,
                         interpret=True)
    want = ref.rbf_accumulate(jnp.asarray(X), sq, jnp.asarray(coef),
                              jnp.asarray(Z), inv)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    # zeroing the garbage rows must not change a single bit of the output
    X2 = X.copy()
    X2[-bm // 2:] = 0.0
    sq2 = jnp.sum(jnp.asarray(X2) ** 2, axis=-1)
    again = rbf_accumulate(jnp.asarray(X2), sq2, jnp.asarray(coef),
                           jnp.asarray(Z), inv, block_m=bm, block_q=bq,
                           interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(again))


@pytest.mark.parametrize("m,K,d,b,bm,bq", [(256, 16, 100, 16, 128, 4),
                                           (512, 64, 300, 32, 256, 8)])
def test_ell_rbf_accumulate_sweep(m, K, d, b, bm, bq):
    from repro.kernels.rbf_row import ell_rbf_accumulate
    r = np.random.default_rng(m + K)
    cols = r.integers(0, d, size=(m, K)).astype(np.int32)
    vals = r.normal(size=(m, K)).astype(np.float32)
    for i in range(m):
        t = r.integers(0, K)
        vals[i, t:] = 0.0
        cols[i, t:] = 0
    coef = r.normal(size=(m,)).astype(np.float32)
    coef[-bm // 2:] = 0.0
    Z = r.normal(size=(b, d)).astype(np.float32)
    sq = jnp.sum(jnp.asarray(vals) ** 2, axis=-1)
    inv = jnp.float32(0.2)
    got = ell_rbf_accumulate(jnp.asarray(vals), jnp.asarray(cols), sq,
                             jnp.asarray(coef), jnp.asarray(Z), inv,
                             block_m=bm, block_q=bq, interpret=True)
    want = ref.ell_rbf_accumulate(jnp.asarray(vals), jnp.asarray(cols), sq,
                                  jnp.asarray(coef), jnp.asarray(Z), inv)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_accumulate_ops_wrappers():
    """ops-level entry points: pad to lane multiples, pick blocks, and fall
    back to the oracle on sizes that fit no grid."""
    r = np.random.default_rng(5)
    for m, b in [(256, 64), (300, 37)]:       # second: oracle fallback
        X = jnp.asarray(r.normal(size=(m, 20)).astype(np.float32))
        sq = jnp.sum(X * X, axis=-1)
        coef = jnp.asarray(r.normal(size=(m,)).astype(np.float32))
        Z = jnp.asarray(r.normal(size=(b, 20)).astype(np.float32))
        got = ops.rbf_accumulate(X, sq, coef, Z, jnp.float32(0.5))
        want = ref.rbf_accumulate(X, sq, coef, Z, jnp.float32(0.5))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
