"""Per-architecture smoke tests (reduced configs, one forward + one train
step on CPU, shape + finiteness assertions) and decode/forward consistency."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import configs
from repro.models.api import build
from repro.models import common
from repro.optim import adamw


def _batch_for(cfg, B=2, L=32, seed=0):
    r = np.random.default_rng(seed)
    tgt = jnp.asarray(r.integers(0, cfg.vocab_size, (B, L)), dtype=jnp.int32)
    if cfg.frontend == "embeds":
        return {"embeds": jnp.asarray(
            r.normal(size=(B, L, cfg.d_model)).astype(np.float32)),
            "targets": tgt}
    return {"tokens": tgt, "targets": tgt}


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = configs.smoke_config(arch)
    model = build(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    logits, aux = jax.jit(lambda p, b: model.forward(p, cfg, b))(params,
                                                                 batch)
    B, L = batch["targets"].shape
    assert logits.shape == (B, L, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())

    # one optimizer step moves params and keeps everything finite
    def loss_fn(p):
        lg, a = model.forward(p, cfg, batch)
        loss, _ = common.cross_entropy(lg, batch["targets"])
        return loss + 0.01 * a

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    opt = adamw.init(params)
    new_p, opt, m = adamw.update(adamw.AdamWConfig(lr=1e-3), grads, opt,
                                 params)
    assert bool(jnp.isfinite(m["grad_norm"]))
    moved = jax.tree.reduce(
        lambda a, b: a or b,
        jax.tree.map(lambda a, b: bool(jnp.any(a != b)), params, new_p))
    assert moved


@pytest.mark.parametrize("arch", ["llama3-8b", "qwen1.5-32b",
                                  "phi3.5-moe-42b-a6.6b", "musicgen-large",
                                  "xlstm-125m", "zamba2-1.2b"])
def test_decode_matches_forward(arch):
    cfg = configs.smoke_config(arch)
    model = build(cfg)
    params = model.init(cfg, jax.random.PRNGKey(1))
    batch = _batch_for(cfg, B=2, L=16, seed=1)
    logits, _ = model.forward(params, cfg, batch)
    cache = model.init_cache(cfg, 2, 16)
    dec = jax.jit(lambda p, c, b: model.decode(p, cfg, c, b))
    errs = []
    for t in range(8):
        if cfg.frontend == "embeds":
            step = {"embeds": batch["embeds"][:, t: t + 1]}
        else:
            step = {"tokens": batch["tokens"][:, t: t + 1]}
        lg, cache = dec(params, cache, step)
        errs.append(float(jnp.abs(lg[:, 0] - logits[:, t]).max()))
    assert max(errs) < 5e-3, errs


def test_moe_capacity_drops_and_aux_loss():
    cfg = dataclasses.replace(configs.smoke_config("phi3.5-moe-42b-a6.6b"),
                              capacity_factor=0.25)
    model = build(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg, B=2, L=64)
    logits, aux = model.forward(params, cfg, batch)
    assert bool(jnp.isfinite(logits).all())   # dropped tokens still finite
    assert float(aux) > 0.5                    # load-balance term is live


def test_scan_and_unroll_agree():
    for arch in ["yi-34b", "xlstm-125m", "zamba2-1.2b"]:
        cfg = configs.smoke_config(arch)
        model = build(cfg)
        params = model.init(cfg, jax.random.PRNGKey(2))
        batch = _batch_for(cfg, B=2, L=32, seed=2)
        l1, _ = model.forward(params, cfg, batch)
        l2, _ = model.forward(
            params, dataclasses.replace(cfg, scan_layers=False), batch)
        assert float(jnp.abs(l1 - l2).max()) < 1e-4


def test_remat_matches_no_remat():
    cfg = configs.smoke_config("llama3-8b")
    model = build(cfg)
    params = model.init(cfg, jax.random.PRNGKey(3))
    batch = _batch_for(cfg, B=2, L=32, seed=3)

    def loss(p, c):
        lg, _ = model.forward(p, c, batch)
        return common.cross_entropy(lg, batch["targets"])[0]

    c_remat = dataclasses.replace(cfg, remat="full")
    g1 = jax.grad(lambda p: loss(p, cfg))(params)
    g2 = jax.grad(lambda p: loss(p, c_remat))(params)
    err = jax.tree.reduce(max, jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()), g1, g2))
    assert err < 1e-5


def test_tiny_lm_training_learns():
    """A few steps on structured tokens should cut the loss measurably."""
    from repro.data import TokenPipeline
    cfg = configs.smoke_config("llama3-8b")
    model = build(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    opt = adamw.init(params)
    ocfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=5, decay_steps=100)
    tp = TokenPipeline(cfg.vocab_size, batch=8, seq_len=64, seed=0)

    @jax.jit
    def step(params, opt, tokens, targets):
        def loss_fn(p):
            lg, _ = model.forward(p, cfg, {"tokens": tokens})
            return common.cross_entropy(lg, targets)[0]
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt, _ = adamw.update(ocfg, grads, opt, params)
        return params, opt, loss

    losses = []
    for i in range(30):
        b = tp.batch_at(i)
        params, opt, loss = step(params, opt, jnp.asarray(b["tokens"]),
                                 jnp.asarray(b["targets"]))
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])
