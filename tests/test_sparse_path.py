"""Block-ELL sparse data plane: kernel parity + end-to-end training.

Covers the paper's sparse-format storage (Sec. 2.2 / Fig. 1b) as realized
by the dense/ELL data-plane abstraction: Pallas ELL kernels vs the jnp
oracles, ELL reference kernels vs the dense reference on densified inputs,
and full ``format='ell'`` training runs (single-host and multi-device)
matching the dense path's solution while using less buffer memory.
Adaptive-K coverage: CSR ingest (``CSRStore`` streaming CSR->ELL fills,
never building dense X), per-buffer K recompaction at physical compaction,
and fixed-K vs adaptive-K trajectory parity.
"""
import json
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import SVMConfig, SMOSolver, dataplane, train
from repro.core import kernel_fns
from repro.data import make_sparse, to_csr, to_ell
from repro.kernels import ops, ref
from test_distributed import run_sub


def _sparse_ell(n, d, density, seed=0):
    X, _ = make_sparse(n, d, density, seed=seed)
    ell = to_ell(X)
    return (X, jnp.asarray(ell.vals), jnp.asarray(ell.cols),
            jnp.asarray(ell.sq_norms()))


# ------------------------------------------------------------- kernel parity
@pytest.mark.parametrize("n,d,density", [(256, 100, 0.10), (512, 300, 0.05),
                                         (512, 2048, 0.01)])
def test_ell_rows2_matches_oracle_and_dense(n, d, density):
    X, vals, cols, sq = _sparse_ell(n, d, density, seed=n)
    r = np.random.default_rng(n)
    z2 = jnp.asarray(r.normal(size=(2, d)).astype(np.float32))
    inv = jnp.float32(0.05)
    got = ops.ell_kernel_rows2(vals, cols, sq, z2, inv)      # Pallas path
    want = ref.ell_kernel_rows2(vals, cols, sq, z2, inv)     # jnp oracle
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    # and the ELL semantics agree with the dense kernel on the same data
    Xj = jnp.asarray(X)
    dense = kernel_fns.rbf_rows2(Xj, jnp.sum(Xj * Xj, -1), z2, inv)
    np.testing.assert_allclose(want, dense, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("n,d,density", [(256, 123, 0.10), (512, 512, 0.03)])
def test_ell_gamma_update_matches_oracle(n, d, density):
    _, vals, cols, sq = _sparse_ell(n, d, density, seed=n + 1)
    r = np.random.default_rng(n + 1)
    z2 = jnp.asarray(r.normal(size=(2, d)).astype(np.float32))
    g = jnp.asarray(r.normal(size=(n,)).astype(np.float32))
    c2 = jnp.asarray(r.normal(size=(2,)).astype(np.float32))
    inv = jnp.float32(0.1)
    got = ops.ell_fused_gamma_update("rbf", vals, cols, sq, g, z2, c2, inv)
    want = ref.ell_gamma_update(vals, cols, sq, g, z2, c2, inv)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_to_ell_roundtrip_and_memory():
    X, _ = make_sparse(200, 1024, 0.02, seed=3)
    ell = to_ell(X)
    np.testing.assert_array_equal(ell.to_dense(), X)
    assert ell.memory_bytes() < X.nbytes          # density 2% << d/2K


def test_ell_cross_kernel_matches_full_matrix():
    X, vals, cols, sq = _sparse_ell(128, 300, 0.08, seed=5)
    Z = jnp.asarray(np.random.default_rng(5)
                    .normal(size=(17, 300)).astype(np.float32))
    inv = jnp.float32(0.04)
    got = kernel_fns.ell_cross_kernel("rbf", Z, vals, cols, sq, inv)
    want = kernel_fns.full_kernel_matrix("rbf", Z, jnp.asarray(X), inv)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------- adaptive-K data plane
def test_csr_store_fills_match_ell_store():
    X, _ = make_sparse(200, 300, 0.05, seed=7)
    se = dataplane.make_store(X, "ell")
    sc = dataplane.make_store(to_csr(X), "ell")
    assert isinstance(sc, dataplane.CSRStore)
    assert sc.K == se.buffer_K(np.arange(200)) == 128
    rows = np.array([3, 17, 0, 199, 58])
    for K in (128, 256):
        be = se.alloc(rows.size, K)
        bc = sc.alloc(rows.size, K)
        se.fill(be, slice(0, rows.size), rows)
        sc.fill(bc, slice(0, rows.size), rows)
        # identical semantics (CSR fill packs identically ordered prefixes)
        np.testing.assert_array_equal(be[0], bc[0])
        np.testing.assert_array_equal(be[1], bc[1])
    np.testing.assert_allclose(sc.dense_rows(rows), X[rows], atol=0)
    # per-subset adaptive K: a low-nnz subset needs fewer lanes than ingest
    v, c = sc.ell_rows(rows)
    assert v.shape == (rows.size, sc.buffer_K(rows))


def test_make_store_validates_explicit_K():
    X, _ = make_sparse(64, 256, 0.05, seed=8)
    # ragged explicit K is rounded up to a whole number of lanes, not
    # passed through to the Pallas tiling path
    s = dataplane.make_store(X, "ell", ell_K=130, ell_lane=128)
    assert s.K == 256
    s = dataplane.make_store(X, "ell", ell_K=140, ell_lane=64)
    assert s.K == 192
    with pytest.raises(ValueError):
        dataplane.make_store(X, "ell", ell_K=0)
    # CSR ingest honors the same pin (stable trace shapes across refits)
    s = dataplane.make_store(to_csr(X), "ell", ell_K=130, ell_lane=128)
    assert s.K == 256
    assert s.alloc(8)[0].shape == (8, 256)
    # CSR input: explicit K that cannot hold the densest row is an error
    with pytest.raises(ValueError):
        dataplane.make_store(to_csr(np.ones((4, 300), np.float32)), "ell",
                             ell_K=128)


def test_csr_tuple_ingest():
    """The documented (data, indices, indptr, shape) triplet form trains."""
    X, y = make_sparse(256, 200, 0.05, seed=9)
    csr = to_csr(X)
    tup = (csr.data, csr.indices, csr.indptr, csr.shape)
    s = SMOSolver(SVMConfig(format="ell", C=4.0, sigma2=4.0,
                            heuristic="single1000"))
    mt = s.fit(tup, y)
    assert isinstance(s._store, dataplane.CSRStore)
    md = train(X, y, C=4.0, sigma2=4.0, heuristic="single1000")
    assert mt.stats.iterations == md.stats.iterations
    assert abs(mt.dual_objective() - md.dual_objective()) < 1e-2


def test_csr_ingest_training_matches_dense():
    """format='ell' fit from CSR input — no dense host X ever allocated."""
    X, y = make_sparse(600, 400, 0.04, seed=0)
    kw = dict(C=4.0, sigma2=4.0, heuristic="multi5pc", chunk_iters=64)
    md = train(X, y, **kw)
    s = SMOSolver(SVMConfig(format="ell", **kw))
    mc = s.fit(to_csr(X), y)
    assert isinstance(s._store, dataplane.CSRStore)
    assert not hasattr(s._store, "X")         # nothing dense to lean on
    assert mc.stats.converged
    rel = abs(mc.dual_objective() - md.dual_objective()) \
        / abs(md.dual_objective())
    assert rel < 1e-3, rel
    np.testing.assert_array_equal(np.flatnonzero(mc.alpha > 0),
                                  np.flatnonzero(md.alpha > 0))
    assert (mc.predict(X) == md.predict(X)).mean() > 0.999


_SKEWED_CACHE = {}


def _skewed_sparse(seed=2):
    """Base sparse set + heavy *easy* rows: near-duplicates of the largest-
    margin non-SVs with many tiny extra nonzeros. The heavy rows dominate
    the ingest K but are shrunk away early, so adaptive recompaction can
    drop the lane budget."""
    if seed in _SKEWED_CACHE:
        return _SKEWED_CACHE[seed]
    X, y = make_sparse(1200, 512, 0.02, seed=seed, noise=0.05,
                       label_noise=0.0, margin=0.5)
    kw = dict(C=2.0, sigma2=80.0, heuristic="single5pc", chunk_iters=128,
              min_buffer=128)
    md = train(X, y, **kw)
    score = np.abs(md.decision_function(X))
    easy = np.flatnonzero(md.alpha == 0)
    heavy = easy[np.argsort(-score[easy])][:64]
    rng = np.random.default_rng(0)
    Xh = X[heavy].copy()
    for i in range(Xh.shape[0]):
        zero = np.flatnonzero(Xh[i] == 0)
        pick = rng.choice(zero, 360, replace=False)
        Xh[i, pick] = 1e-4 * rng.normal(size=360).astype(np.float32)
    _SKEWED_CACHE[seed] = (np.vstack([X, Xh]),
                           np.concatenate([y, y[heavy]]), kw)
    return _SKEWED_CACHE[seed]


def test_adaptive_K_drops_at_compaction():
    Xa, ya, kw = _skewed_sparse()
    nnz = (Xa != 0).sum(axis=1)
    assert nnz.max() > 2 * 128 >= 4 * nnz.min()    # genuinely skewed rows
    m = train(Xa, ya, format="ell", **kw)
    assert m.stats.converged
    assert m.stats.compactions >= 1
    ks = m.stats.buffer_K
    assert len(ks) == len(m.stats.buffer_sizes)
    assert min(ks) < ks[0], ks                     # K shrank mid-run
    # strictly decreasing across at least one physical compaction
    assert any(b < a for a, b in zip(ks, ks[1:])), ks
    assert all(k % 128 == 0 for k in ks)
    for per_shard in m.stats.shard_K:
        assert max(per_shard) <= max(ks)
    # and the adaptive path solves the same problem as dense
    mdense = train(Xa, ya, **kw)
    rel = abs(m.dual_objective() - mdense.dual_objective()) \
        / abs(mdense.dual_objective())
    assert rel < 1e-3, rel


def test_fixed_K_matches_adaptive_K_trajectory():
    """ell_adaptive only changes buffer geometry, never the optimization:
    padding lanes contribute exactly 0 to every gather-FMA."""
    Xa, ya, kw = _skewed_sparse()
    ma = train(Xa, ya, format="ell", **kw)
    mf = train(Xa, ya, format="ell", ell_adaptive=False, **kw)
    assert ma.stats.iterations == mf.stats.iterations
    np.testing.assert_allclose(ma.alpha, mf.alpha, atol=1e-6)
    assert len(set(mf.stats.buffer_K)) == 1       # fixed-K really is fixed
    assert min(ma.stats.buffer_K) < mf.stats.buffer_K[0]


# --------------------------------------------------------------- end-to-end
def test_ell_training_matches_dense():
    X, y = make_sparse(600, 400, 0.04, seed=0)
    kw = dict(C=4.0, sigma2=4.0, heuristic="multi5pc", chunk_iters=64)
    md = train(X, y, **kw)
    me = train(X, y, format="ell", **kw)
    assert me.stats.converged
    rel = abs(me.dual_objective() - md.dual_objective()) \
        / abs(md.dual_objective())
    assert rel < 1e-2, rel
    # same support set and matching predictions
    sv_d = np.flatnonzero(md.alpha > 0)
    sv_e = np.flatnonzero(me.alpha > 0)
    np.testing.assert_array_equal(sv_d, sv_e)
    assert (md.predict(X) == me.predict(X)).mean() > 0.999
    np.testing.assert_allclose(me.decision_function(X[:64]),
                               md.decision_function(X[:64]),
                               rtol=1e-3, atol=1e-3)


def test_ell_buffer_memory_below_dense():
    X, y = make_sparse(512, 2048, 0.03, seed=1)
    sd = dataplane.make_store(X, "dense")
    se = dataplane.make_store(X, "ell")
    bd = sd.to_device(sd.alloc(512), jnp.asarray)
    be = se.to_device(se.alloc(512), jnp.asarray)
    assert be.memory_bytes() < bd.memory_bytes()
    # crossover rule: ELL spends 2K+1 floats/row vs d+1 dense
    assert 2 * se.K < X.shape[1]


def test_ell_shrinking_compaction_and_reconstruction():
    X, y = make_sparse(1500, 512, 0.05, seed=2, noise=0.05, label_noise=0.0,
                       margin=0.5)
    m = train(X, y, C=2.0, sigma2=80.0, heuristic="single5pc",
              chunk_iters=128, min_buffer=128, format="ell")
    assert m.stats.converged
    assert m.stats.shrink_events > 0
    assert m.stats.compactions >= 1          # ELL rows physically moved
    assert m.stats.reconstructions >= 1      # ELL Alg. 6 ran
    assert min(m.stats.buffer_sizes) < max(m.stats.buffer_sizes)


def test_ell_pallas_path_equals_jnp_path():
    X, y = make_sparse(512, 300, 0.06, seed=4)
    kw = dict(C=4.0, sigma2=4.0, heuristic="single1000", format="ell")
    m1 = train(X, y, **kw)
    m2 = train(X, y, use_pallas=True, **kw)
    assert m1.stats.iterations == m2.stats.iterations
    assert abs(m1.dual_objective() - m2.dual_objective()) < 1e-2


def test_parallel_ell_matches_sequential_4dev():
    out = run_sub("""
        import numpy as np, json
        from repro.core import SVMConfig, train, dataplane
        from repro.core.parallel import ParallelSMOSolver
        from repro.core.reconstruct import reconstruct_gamma_store
        from repro.data import make_sparse, to_csr
        X, y = make_sparse(640, 400, 0.04, seed=0)
        kw = dict(C=4.0, sigma2=4.0, heuristic='multi5pc', chunk_iters=64)
        seq = train(X, y, **kw)
        # parallel fit ingesting CSR directly (CSRStore data plane)
        ps = ParallelSMOSolver(SVMConfig(format='ell', **kw))
        par = ps.fit(to_csr(X), y)
        csr_store = type(ps._store).__name__
        # adaptive-K ring reconstruction vs the host-store path, for both
        # ELL-family stores (ring payload is SV-masked at the SV set's K)
        rng = np.random.default_rng(1)
        alpha = (rng.random(640) * (rng.random(640) < 0.3)).astype(np.float32)
        stale = np.flatnonzero(rng.random(640) < 0.5)
        errs = []
        for src in (X, to_csr(X)):
            s = ParallelSMOSolver(SVMConfig(sigma2=2.0, format='ell'))
            s._store = dataplane.make_store(src, 'ell')
            ring = s._reconstruct(y, alpha, stale)
            host = reconstruct_gamma_store('rbf', s._store, y, alpha, stale,
                                           0.25)
            errs.append(float(np.abs(ring - host).max()))
        print(json.dumps({
            'seq': [seq.stats.iterations, seq.dual_objective()],
            'par': [par.stats.iterations, par.dual_objective(),
                    par.stats.converged, par.stats.reconstructions],
            'csr_store': csr_store,
            'buffer_K': par.stats.buffer_K,
            'ring_err': max(errs)}))
    """, devices=4)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["par"][2]                         # converged
    assert res["par"][3] >= 1                    # ELL reconstruction ran
    assert res["csr_store"] == "CSRStore"        # no dense host X in play
    assert res["buffer_K"] and all(k % 128 == 0 for k in res["buffer_K"])
    rel = abs(res["par"][1] - res["seq"][1]) / abs(res["seq"][1])
    assert rel < 1e-2, res
    assert res["ring_err"] < 1e-3, res
