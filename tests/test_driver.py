"""Epoch-driver pipeline tests: device-side compaction + cache remap must
reproduce the host-path trajectory bit-identically (dense + ELL,
single-host + parallel), save->resume must survive a device-side
compaction, the consolidated pow2 utilities, and the segmented-LRU cache
policy (exactness + scan resistance)."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import SMOSolver, SVMConfig, dataplane, rowcache, train, util
from repro.data import make_sparse
from test_distributed import run_sub

# wide-margin sparse problem: shrinks aggressively under the Multi policy,
# so every fit goes through >= 1 physical compaction AND >= 1
# reconstruction un-shrink — both halves of the remap contract
SHRINKY = dict(C=2.0, sigma2=40.0, heuristic="multi5pc", chunk_iters=64,
               min_buffer=64, eps=1e-3)


def _shrinky_data(n=900, d=300):
    return make_sparse(n, d, 0.05, seed=3, noise=0.05, label_noise=0.0,
                       margin=0.5)


# ------------------------------------------------------------------ helpers
def test_pow2_helpers():
    assert util.next_pow2(0) == 1
    assert util.next_pow2(1) == 1
    assert util.next_pow2(2) == 2
    assert util.next_pow2(5) == 8
    assert util.next_pow2(1024) == 1024
    assert util.bucket_pow2(0, 64) == 64
    assert util.bucket_pow2(-3, 64) == 64
    assert util.bucket_pow2(3, 8) == 8
    assert util.bucket_pow2(100, 8) == 128
    assert util.bucket_pow2(100, 8, hi=64) == 64
    assert util.bucket_pow2(1 << 40, 8) == 1 << 30


def test_compact_plan_reference():
    """Device gather plan == the host rebuild's balanced contiguous layout
    on a hand-checked case."""
    keep = np.array([True, False, True, True, False, True, True, False])
    src, valid = dataplane.compact_plan(jnp.asarray(keep), jnp.int32(5),
                                        p=2, m_per=4)
    # survivors [0, 2, 3, 5, 6] dealt 3/2 over two shards of 4 slots
    np.testing.assert_array_equal(np.asarray(valid),
                                  [1, 1, 1, 0, 1, 1, 0, 0])
    assert np.asarray(src)[np.asarray(valid)].tolist() == [0, 2, 3, 5, 6]


def test_compact_plan_property():
    """Property test: for random keep masks and shard counts, the device
    plan reproduces the host layout exactly (incl. empty shards)."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(deadline=None, max_examples=60)
    @given(st.integers(1, 4), st.lists(st.booleans(), min_size=1,
                                       max_size=48))
    def check(p, keepl):
        keep = np.asarray(keepl, bool)
        n_active = int(keep.sum())
        m_per = max(1, -(-n_active // p))
        src, valid = dataplane.compact_plan(
            jnp.asarray(keep), jnp.int32(n_active), p=p, m_per=m_per)
        src, valid = np.asarray(src), np.asarray(valid)
        pos = np.flatnonzero(keep)
        base, extra = divmod(n_active, p)
        expect = np.full(p * m_per, -1, np.int64)
        off = 0
        for q in range(p):
            cnt = base + (1 if q < extra else 0)
            expect[q * m_per: q * m_per + cnt] = pos[off: off + cnt]
            off += cnt
        np.testing.assert_array_equal(valid, expect >= 0)
        np.testing.assert_array_equal(src[valid], expect[expect >= 0])

    check()


def test_remap_cache_device_matches_host():
    """Column re-gather of the (S, M) value table: device == host on a
    compaction (subset) plan; tags/stamps/counters ride through."""
    c = rowcache.init_cache(3, 6)
    vals = np.arange(18, dtype=np.float32).reshape(3, 6)
    c = c._replace(tags=jnp.asarray([4, 9, -1], jnp.int32),
                   vals=jnp.asarray(vals), hits=jnp.int32(5),
                   misses=jnp.int32(7))
    old_idx = np.array([2, 4, 7, 9, -1, -1])
    new_idx = np.array([4, 9, -1, -1])
    keep = jnp.asarray(np.isin(old_idx, [4, 9]))
    src, valid = dataplane.compact_plan(keep, jnp.int32(2), p=1, m_per=4)
    host = rowcache.remap_cache(c, old_idx, new_idx)
    dev = rowcache.remap_cache_device(c, src, valid)
    np.testing.assert_array_equal(np.asarray(dev.vals),
                                  np.asarray(host.vals))
    np.testing.assert_array_equal(dev.tags, host.tags)
    assert (int(dev.hits), int(dev.misses)) == (5, 7)
    assert rowcache.remap_cache_device(None, src, valid) is None


# -------------------------------------------- device == host (the core test)
@pytest.mark.parametrize("fmt", ["dense", "ell"])
@pytest.mark.parametrize("cache", [False, True])
def test_device_compaction_bitwise_parity(fmt, cache):
    """Device-side compaction (+ cache remap) must reproduce the host
    rebuild path bit-for-bit: same iteration count, bitwise-equal alpha,
    identical buffer-geometry trajectory — across >= 1 compaction and
    >= 1 reconstruction."""
    X, y = _shrinky_data()
    kw = dict(format=fmt, row_cache=cache, **SHRINKY)
    md = train(X, y, **kw)                              # device (default)
    mh = train(X, y, compact_backend="host", **kw)      # parity oracle
    assert md.stats.compactions >= 1
    assert md.stats.reconstructions >= 1
    assert md.stats.converged
    assert md.stats.iterations == mh.stats.iterations
    np.testing.assert_array_equal(md.alpha, mh.alpha)
    assert md.stats.buffer_sizes == mh.stats.buffer_sizes
    assert md.stats.buffer_K == mh.stats.buffer_K
    assert md.stats.shard_K == mh.stats.shard_K
    assert md.stats.compactions == mh.stats.compactions
    if cache:
        # identical trajectories -> identical hit/miss history through the
        # device remap
        assert (md.stats.cache_hits, md.stats.cache_misses) \
            == (mh.stats.cache_hits, mh.stats.cache_misses)


def test_csr_explicit_zeros_extent_parity():
    """CSR inputs with explicitly stored zeros (thresholding without
    eliminate_zeros()): the host store's adaptive-K extent must measure
    trailing *nonzeros* like the device scan does, or the two backends pick
    different lane buckets. Trains from raw CSR arrays carrying stored
    zeros and asserts the full geometry trajectory matches."""
    from repro.data import sparse as spfmt
    X, y = _shrinky_data()
    csr = spfmt.to_csr(X)
    # re-insert explicit zeros: zero out ~30% of stored values, keep them
    rng = np.random.default_rng(0)
    data = csr.data.copy()
    data[rng.random(data.size) < 0.3] = 0.0
    zcsr = spfmt.CSRMatrix(data, csr.indices, csr.indptr, csr.shape)
    # host extent == device extent on the filled buffer, rowwise
    from repro.core import dataplane as dp
    store = dp.CSRStore(zcsr)
    rows = np.arange(zcsr.shape[0])
    buf = store.alloc(rows.size, store.K)
    store.fill(buf, slice(0, rows.size), rows)
    dev_ext = np.asarray(dataplane.ell_extents(jnp.asarray(buf[0])))
    np.testing.assert_array_equal(store.row_extent, dev_ext)
    kw = dict(format="ell", **SHRINKY)
    md = train(zcsr, y, **kw)
    mh = train(zcsr, y, compact_backend="host", **kw)
    assert md.stats.compactions >= 1
    assert md.stats.iterations == mh.stats.iterations
    np.testing.assert_array_equal(md.alpha, mh.alpha)
    assert md.stats.buffer_K == mh.stats.buffer_K
    assert md.stats.shard_K == mh.stats.shard_K


def test_parallel_device_compaction_parity_4dev():
    out = run_sub("""
        import numpy as np, json
        from repro.core import SVMConfig
        from repro.core.parallel import ParallelSMOSolver
        from repro.data import make_sparse
        X, y = make_sparse(900, 300, 0.05, seed=3, noise=0.05,
                           label_noise=0.0, margin=0.5)
        kw = dict(C=2.0, sigma2=40.0, heuristic='multi5pc', chunk_iters=64,
                  min_buffer=64, row_cache=True)
        res = {}
        for fmt in ('dense', 'ell'):
            md = ParallelSMOSolver(SVMConfig(format=fmt, **kw)).fit(X, y)
            mh = ParallelSMOSolver(SVMConfig(format=fmt,
                                             compact_backend='host',
                                             **kw)).fit(X, y)
            res[fmt] = dict(
                iters=[md.stats.iterations, mh.stats.iterations],
                compactions=[md.stats.compactions, mh.stats.compactions],
                recon=md.stats.reconstructions,
                alpha_eq=bool(np.array_equal(md.alpha, mh.alpha)),
                bufs_eq=md.stats.buffer_sizes == mh.stats.buffer_sizes,
                shard_K_eq=md.stats.shard_K == mh.stats.shard_K,
                conv=bool(md.stats.converged))
        print(json.dumps(res))
    """, devices=4)
    import json
    res = json.loads(out.strip().splitlines()[-1])
    for fmt in ("dense", "ell"):
        r = res[fmt]
        assert r["conv"], r
        assert r["compactions"][0] >= 1, r       # device path exercised
        assert r["compactions"][0] == r["compactions"][1], r
        assert r["recon"] >= 1, r                # un-shrink exercised
        assert r["iters"][0] == r["iters"][1], r
        assert r["alpha_eq"], r                  # bitwise
        assert r["bufs_eq"] and r["shard_K_eq"], r


# ------------------------------------------------------------ save -> resume
def test_resume_across_device_compaction(tmp_path):
    """Interrupt after >= 1 *device-side* compaction; the resumed run must
    rejoin the uninterrupted trajectory (the checkpoint's alpha/gamma come
    from the device masters, which hold drop-time values for rows shrunk
    away before the save)."""
    X, y = _shrinky_data()
    full = train(X, y, **SHRINKY)
    assert full.stats.converged and full.stats.compactions >= 1
    cut = int(full.stats.iterations * 0.6)
    d = str(tmp_path)
    m1 = SMOSolver(SVMConfig(checkpoint_dir=d, max_iters=cut,
                             **SHRINKY)).fit(X, y)
    assert m1.stats.compactions >= 1, \
        "cut landed before the first compaction"
    assert m1.stats.iterations <= cut < full.stats.iterations
    m2 = SMOSolver(SVMConfig(checkpoint_dir=d, resume=True,
                             **SHRINKY)).fit(X, y)
    assert m2.stats.converged
    assert m2.stats.iterations == full.stats.iterations
    np.testing.assert_allclose(m2.alpha, full.alpha, atol=1e-6)


# ---------------------------------------------------------------- SLRU cache
def test_slru_exactness_and_config_validation():
    X, y = _shrinky_data(n=500, d=200)
    kw = dict(format="ell", **SHRINKY)
    m0 = train(X, y, **kw)
    m1 = train(X, y, row_cache=True, row_cache_policy="slru", **kw)
    assert m1.stats.iterations == m0.stats.iterations
    np.testing.assert_array_equal(m1.alpha, m0.alpha)
    assert m1.stats.cache_hits + m1.stats.cache_misses \
        == 2 * m1.stats.iterations
    with pytest.raises(ValueError, match="row_cache_policy"):
        train(X, y, row_cache=True, row_cache_policy="mru", **kw)
    with pytest.raises(ValueError, match="compact_backend"):
        train(X, y, compact_backend="gpu", **kw)


def test_slru_scan_resistance():
    """The pathology SLRU exists for: a one-shot scan wider than the slot
    count evicts an LRU cache's entire hot set, but SLRU's protected
    segment (populated by re-referenced rows) survives it."""
    S, m = 8, 4
    hot = (1, 2, 3)

    def run(policy):
        c = rowcache.init_cache(S, m)
        def acc(c, g):
            return rowcache.get_row(c, jnp.int32(g),
                                    lambda: jnp.full((m,), float(g)), policy)
        for g in hot + hot:        # second pass promotes (slru) / refreshes
            _, c = acc(c, g)
        before = int(c.hits)
        for g in range(100, 116):  # scan: 2x the slot count, all cold
            _, c = acc(c, g)
        for g in hot:              # does the hot set still hit?
            row, c = acc(c, g)
            np.testing.assert_array_equal(row, np.full((m,), float(g)))
        return int(c.hits) - before

    assert run("lru") == 0         # scan flushed everything
    assert run("slru") == len(hot)  # protected segment survived the scan


def test_slru_protected_capacity_bounded():
    """Promotions past the protected capacity demote the protected LRU —
    the protected segment can never swallow the whole table."""
    S, m = 4, 2                    # cap = 2
    c = rowcache.init_cache(S, m)
    def acc(c, g):
        return rowcache.get_row(c, jnp.int32(g),
                                lambda: jnp.full((m,), float(g)), "slru")
    for g in (1, 2, 3, 1, 2, 3):   # three promotions through cap 2
        _, c = acc(c, g)
    assert int(np.sum(np.asarray(c.seg) == 1)) <= S // 2
    # a fresh insert still finds a probationary victim
    _, c = acc(c, 99)
    assert 99 in np.asarray(c.tags).tolist()
