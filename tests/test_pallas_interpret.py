"""Pallas interpret-mode coverage (the CI half of the ROADMAP's
TPU-validation gap): off-TPU, ``kernels.ops`` auto-selects interpret mode,
so these tests drive the actual Pallas kernel bodies — through the cached
hot loop (rows2 + row production behind the LRU cache) and through the
device-side compaction / mirror-reconstruction pipeline steps — rather
than the jnp oracles. Run as its own CI job (`pallas-interpret`)."""
import numpy as np
import pytest
import jax

from repro.core import train
from repro.data import make_sparse

pytestmark = pytest.mark.skipif(
    jax.default_backend() == "tpu",
    reason="interpret-mode suite; on real TPU the kernels compile instead")

KW = dict(C=2.0, sigma2=30.0, heuristic="multi5pc", chunk_iters=64,
          min_buffer=64)


def _data(n=512, d=200):
    return make_sparse(n, d, 0.06, seed=4, noise=0.05, label_noise=0.0,
                       margin=0.5)


@pytest.mark.parametrize("fmt", ["dense", "ell"])
def test_cached_hot_loop_interpret(fmt):
    """The cond-heavy cached loop over interpret-mode Pallas row kernels:
    converges, reconstructs, counts cache traffic, and lands on the same
    solution as the jnp provider path (no bitwise contract across
    backends — the fused Pallas gamma kernel associates differently)."""
    X, y = _data()
    m = train(X, y, format=fmt, use_pallas=True, row_cache=True,
              row_cache_slots=256, **KW)
    ref = train(X, y, format=fmt, use_pallas=False, **KW)
    assert m.stats.converged
    assert m.stats.reconstructions >= 1
    assert m.stats.cache_hits + m.stats.cache_misses > 0
    rel = abs(m.dual_objective() - ref.dual_objective()) \
        / max(abs(ref.dual_objective()), 1e-9)
    assert rel < 1e-2, rel


@pytest.mark.parametrize("fmt", ["dense", "ell"])
def test_serve_accumulate_interpret(fmt):
    """The serve-time fused accumulate kernel bodies (rbf_accumulate /
    ell_rbf_accumulate) through the full inference plane: a Pallas engine
    over a Pallas-trained model must match the jnp host oracle."""
    X, y = _data()
    m = train(X, y, format=fmt, use_pallas=True, **KW)
    rng = np.random.default_rng(7)
    Z = X[rng.integers(0, len(X), 200)].astype(np.float32)
    ref = m.decision_function_host(Z)
    eng = m.serve_engine(use_pallas=True)
    np.testing.assert_allclose(eng.decision_function(Z), ref,
                               rtol=1e-4, atol=2e-5)


@pytest.mark.parametrize("fmt", ["dense", "ell"])
def test_device_pipeline_steps_interpret(fmt):
    """Device compaction and the mirror reconstruction/un-shrink under the
    Pallas hot loop: the pipeline steps themselves are kernel-free jnp
    programs, so their host/device parity must stay BITWISE even when the
    chunk runner executes interpret-mode Pallas kernels."""
    X, y = _data()
    kw = dict(format=fmt, use_pallas=True, row_cache=True, **KW)
    md = train(X, y, compact_backend="device", mirror="device", **kw)
    mh = train(X, y, compact_backend="host", mirror="host", **kw)
    assert md.stats.compactions >= 1 and md.stats.reconstructions >= 1
    assert md.stats.iterations == mh.stats.iterations
    np.testing.assert_array_equal(md.alpha, mh.alpha)
    assert md.stats.buffer_sizes == mh.stats.buffer_sizes
    assert md.stats.shard_K == mh.stats.shard_K
