"""zamba2-1.2b [arXiv:2411.15242; hf]
38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000, ssm_state=64 —
Mamba2 backbone + one shared attention block every 6 layers.
Sub-quadratic: runs long_500k."""
from repro.models.api import ModelConfig

FULL = ModelConfig(
    name="zamba2-1.2b", family="hybrid", n_layers=38, d_model=2048,
    n_heads=32, n_kv_heads=32, d_ff=8192, vocab_size=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, attn_every=6,
    chunk=256, subquadratic=True, dtype="bfloat16", remat="full")

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid", n_layers=4, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256,
    ssm_state=16, ssm_head_dim=16, attn_every=2, chunk=16,
    subquadratic=True, dtype="float32", remat="none")
