"""musicgen-large [arXiv:2306.05284; hf]
48L d_model=2048 32H (kv=32, MHA) d_ff=8192 vocab=2048 (EnCodec codes).
Decoder-only over EnCodec tokens; the EnCodec frontend is a stub —
input_specs() supplies precomputed frame embeddings."""
from repro.models.api import ModelConfig

FULL = ModelConfig(
    name="musicgen-large", family="audio", n_layers=48, d_model=2048,
    n_heads=32, n_kv_heads=32, d_ff=8192, vocab_size=2048,
    frontend="embeds", rope_theta=1e4, dtype="bfloat16", remat="full")

SMOKE = ModelConfig(
    name="musicgen-smoke", family="audio", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=160, vocab_size=64,
    frontend="embeds", dtype="float32", remat="none")
