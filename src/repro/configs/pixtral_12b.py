"""pixtral-12b [hf:mistralai/Pixtral-12B-2409; unverified]
40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.
Backbone only (mistral-nemo body); the pixtral-ViT frontend is a stub —
input_specs() supplies precomputed patch embeddings (B, L, d_model)."""
from repro.models.api import ModelConfig

FULL = ModelConfig(
    name="pixtral-12b", family="vlm", n_layers=40, d_model=5120,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab_size=131072,
    head_dim=128, frontend="embeds", rope_theta=1e6,
    dtype="bfloat16", remat="full")

SMOKE = ModelConfig(
    name="pixtral-smoke", family="vlm", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=160, vocab_size=256,
    frontend="embeds", dtype="float32", remat="none")
