"""Architecture registry: the 10 assigned archs (+ SVM dataset specs live in
repro.data.synthetic). ``--arch <id>`` everywhere resolves through here."""
from __future__ import annotations

import importlib

from repro.configs.shapes import SHAPES, ShapeSpec, applicable, input_specs

_MODULES = {
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "llama4-scout-17b-a16e": "llama4_scout",
    "pixtral-12b": "pixtral_12b",
    "llama3-8b": "llama3_8b",
    "qwen1.5-32b": "qwen15_32b",
    "yi-34b": "yi_34b",
    "qwen2.5-32b": "qwen25_32b",
    "musicgen-large": "musicgen_large",
    "xlstm-125m": "xlstm_125m",
    "zamba2-1.2b": "zamba2_12b",
}

ARCH_IDS = list(_MODULES)


def _mod(arch: str):
    if arch not in _MODULES:
        raise ValueError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def full_config(arch: str):
    return _mod(arch).FULL


def smoke_config(arch: str):
    return _mod(arch).SMOKE
