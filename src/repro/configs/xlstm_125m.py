"""xlstm-125m [arXiv:2405.04517; unverified]
12L d_model=768 4H d_ff=0 vocab=50304 — sLSTM + mLSTM blocks (1 sLSTM per
4-block group; mLSTM pf=2 / sLSTM pf=4/3 gated projections replace the
FFN, hence d_ff=0). Sub-quadratic: runs long_500k."""
from repro.models.api import ModelConfig

FULL = ModelConfig(
    name="xlstm-125m", family="ssm", n_layers=12, d_model=768,
    n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=50304,
    slstm_every=4, chunk=256, subquadratic=True,
    dtype="bfloat16", remat="full")

SMOKE = ModelConfig(
    name="xlstm-smoke", family="ssm", n_layers=4, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=256,
    slstm_every=2, chunk=16, subquadratic=True,
    dtype="float32", remat="none")
