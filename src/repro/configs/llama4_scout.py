"""llama4-scout-17b-a16e [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16 experts
top-1 (early fusion — text path; modality fusion stub not required for
the LM backbone cells)."""
from repro.models.api import ModelConfig

FULL = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe", n_layers=48, d_model=5120,
    n_heads=40, n_kv_heads=8, d_ff=8192, vocab_size=202048,
    n_experts=16, top_k=1, rope_theta=5e5,
    dtype="bfloat16", remat="full")

SMOKE = ModelConfig(
    name="llama4-scout-smoke", family="moe", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=96, vocab_size=256,
    n_experts=4, top_k=1, dtype="float32", remat="none")
