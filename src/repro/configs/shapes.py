"""The assigned input-shape set (one per arch x shape dry-run cell).

``decode_*`` / ``long_*`` lower serve_step (one token against a seq_len KV
cache/state), not train_step. ``long_500k`` requires sub-quadratic sequence
mixing and is only applicable to the SSM/hybrid archs (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.api import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {s.name: s for s in [
    ShapeSpec("train_4k", "train", 4096, 256),
    ShapeSpec("prefill_32k", "prefill", 32768, 32),
    ShapeSpec("decode_32k", "decode", 32768, 128),
    ShapeSpec("long_500k", "decode", 524288, 1),
]}


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runs?, reason-if-skipped)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("pure full-attention arch: 500k dense KV decode is "
                       "quadratic-regime; skipped per DESIGN.md §5")
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input — weak-type-correct,
    shardable, no device allocation (the dry-run contract)."""
    B, L = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    act = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        if cfg.frontend == "embeds":
            return {"embeds": jax.ShapeDtypeStruct((B, L, cfg.d_model), act),
                    "targets": jax.ShapeDtypeStruct((B, L), i32)}
        return {"tokens": jax.ShapeDtypeStruct((B, L), i32),
                "targets": jax.ShapeDtypeStruct((B, L), i32)}
    if shape.kind == "prefill":
        if cfg.frontend == "embeds":
            return {"embeds": jax.ShapeDtypeStruct((B, L, cfg.d_model), act)}
        return {"tokens": jax.ShapeDtypeStruct((B, L), i32)}
    # decode: one new token; the cache ShapeDtypeStructs come from
    # eval_shape(init_cache) in the dry-run driver.
    if cfg.frontend == "embeds":
        return {"embeds": jax.ShapeDtypeStruct((B, 1, cfg.d_model), act)}
    return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
