"""yi-34b [arXiv:2403.04652; hf]
60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000 (llama arch)."""
from repro.models.api import ModelConfig

FULL = ModelConfig(
    name="yi-34b", family="dense", n_layers=60, d_model=7168,
    n_heads=56, n_kv_heads=8, d_ff=20480, vocab_size=64000,
    rope_theta=5e6, dtype="bfloat16", remat="full")

SMOKE = ModelConfig(
    name="yi-34b-smoke", family="dense", n_layers=2, d_model=56,
    n_heads=7, n_kv_heads=1, d_ff=160, vocab_size=256,
    dtype="float32", remat="none")
