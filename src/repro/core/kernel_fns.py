"""Kernel functions for SVM training.

The paper (Sec. 4.1) uses the Gaussian kernel K(x, z) = exp(-||x - z||^2 / (2 sigma^2))
for all experiments; linear and polynomial kernels are "straightforward to use"
(Sec. 4.1) and are provided for completeness.

Kernel *rows* (K(z, X) for one z against the whole active set) are the hot
path of SMO. The paper recomputes them every iteration (Sec. 3.1.1, no
kernel cache); this repo routes all row production through the pluggable
provider layer below (:func:`make_provider`), which hides the storage
format (dense vs block-ELL) and backend (jnp vs Pallas) behind one protocol
and lets the solver slot a device-resident LRU row cache
(``repro.core.rowcache``) in front of it. On TPU the fused Pallas kernels
in ``repro.kernels`` replace the jnp implementations here; these are the
reference/CPU path.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Protocol

import jax
import jax.numpy as jnp

KernelRowFn = Callable[..., jax.Array]


def rbf_row(X: jax.Array, sq_norms: jax.Array, z: jax.Array, inv_2s2: jax.Array) -> jax.Array:
    """K(z, X_i) = exp(-||X_i - z||^2 * inv_2s2) for all rows i.

    ``sq_norms`` holds precomputed ||X_i||^2 so each row costs one GEMV pass.
    """
    d2 = sq_norms - 2.0 * (X @ z) + jnp.dot(z, z)
    return jnp.exp(-jnp.maximum(d2, 0.0) * inv_2s2)


def rbf_rows2(X: jax.Array, sq_norms: jax.Array, z2: jax.Array, inv_2s2: jax.Array) -> jax.Array:
    """Fused two-row RBF: K([z_up; z_low], X) in a single pass over X.

    z2: (2, d). Returns (N, 2). One GEMM instead of two GEMVs — halves HBM
    traffic on X, which dominates the per-iteration cost (see DESIGN.md §7).
    """
    prods = X @ z2.T                                  # (N, 2)
    zn = jnp.sum(z2 * z2, axis=-1)                    # (2,)
    d2 = sq_norms[:, None] - 2.0 * prods + zn[None, :]
    return jnp.exp(-jnp.maximum(d2, 0.0) * inv_2s2)


def linear_row(X: jax.Array, sq_norms: jax.Array, z: jax.Array, inv_2s2: jax.Array) -> jax.Array:
    del sq_norms, inv_2s2
    return X @ z


def linear_rows2(X: jax.Array, sq_norms: jax.Array, z2: jax.Array, inv_2s2: jax.Array) -> jax.Array:
    del sq_norms, inv_2s2
    return X @ z2.T


def poly_row(X: jax.Array, sq_norms: jax.Array, z: jax.Array, inv_2s2: jax.Array,
             degree: int = 3, coef0: float = 1.0) -> jax.Array:
    del sq_norms
    # inv_2s2 doubles as the scale parameter for non-RBF kernels.
    return (inv_2s2 * (X @ z) + coef0) ** degree


def poly_rows2(X: jax.Array, sq_norms: jax.Array, z2: jax.Array, inv_2s2: jax.Array,
               degree: int = 3, coef0: float = 1.0) -> jax.Array:
    del sq_norms
    return (inv_2s2 * (X @ z2.T) + coef0) ** degree


def self_kernel(kernel: str) -> Callable[[jax.Array, jax.Array], jax.Array]:
    """K(x, x). For RBF this is identically 1 — exploited to skip two kernel
    evaluations per iteration (Eq. 12 needs K(up,up) and K(low,low))."""
    if kernel == "rbf":
        return lambda z, inv_2s2: jnp.float32(1.0)
    if kernel == "linear":
        return lambda z, inv_2s2: jnp.dot(z, z)
    if kernel == "poly":
        return lambda z, inv_2s2: (inv_2s2 * jnp.dot(z, z) + 1.0) ** 3
    raise ValueError(f"unknown kernel {kernel!r}")


_ROWS2 = {"rbf": rbf_rows2, "linear": linear_rows2, "poly": poly_rows2}
_ROW = {"rbf": rbf_row, "linear": linear_row, "poly": poly_row}


def get_rows2(kernel: str) -> KernelRowFn:
    try:
        return _ROWS2[kernel]
    except KeyError:
        raise ValueError(f"unknown kernel {kernel!r}") from None


def get_row(kernel: str) -> KernelRowFn:
    try:
        return _ROW[kernel]
    except KeyError:
        raise ValueError(f"unknown kernel {kernel!r}") from None


# --------------------------------------------------------------------------
# Block-ELL sparse sample storage (paper Sec. 2.2 / Fig. 1b; DESIGN.md §2).
# The query z stays dense; samples are (vals, cols) rows padded to K
# nonzeros. <x_i, z> = sum_k vals[i,k] * z[cols[i,k]] — a lane-wise gather
# plus FMA. Padding slots (val=0, col=0) contribute exactly 0.

def ell_dots(vals: jax.Array, cols: jax.Array, z: jax.Array) -> jax.Array:
    """<x_i, z> for every ELL row i. vals/cols: (M, K), z: (d,). -> (M,)."""
    return jnp.sum(vals * jnp.take(z, cols, axis=0), axis=-1)


def ell_dots2(vals: jax.Array, cols: jax.Array, z2: jax.Array) -> jax.Array:
    """<x_i, z_j> for two dense queries. z2: (2, d). -> (M, 2).

    Batch-major reduction (reduce K per query, then transpose) rather than
    ``einsum('mk,jmk->mj')``: the einsum's query-minor output layout made
    XLA reduce the two columns with *different* instruction schedules, so
    K(z, .) computed in slot 0 was not bitwise equal to the same row
    computed in slot 1 — which breaks the row cache's exactness contract
    (a row cached from one pair position must serve the other position
    bit-identically). The batch-major form is position-symmetric and
    measured slightly faster on CPU.
    """
    zg = jnp.take(z2, cols, axis=1)                   # (2, M, K)
    return jnp.sum(vals[None, :, :] * zg, axis=-1).T  # (M, 2)


def ell_rbf_row(vals, cols, sq_norms, z, inv_2s2):
    d2 = sq_norms - 2.0 * ell_dots(vals, cols, z) + jnp.dot(z, z)
    return jnp.exp(-jnp.maximum(d2, 0.0) * inv_2s2)


def ell_rbf_rows2(vals, cols, sq_norms, z2, inv_2s2):
    zn = jnp.sum(z2 * z2, axis=-1)                    # (2,)
    d2 = sq_norms[:, None] - 2.0 * ell_dots2(vals, cols, z2) + zn[None, :]
    return jnp.exp(-jnp.maximum(d2, 0.0) * inv_2s2)


def ell_linear_row(vals, cols, sq_norms, z, inv_2s2):
    del sq_norms, inv_2s2
    return ell_dots(vals, cols, z)


def ell_linear_rows2(vals, cols, sq_norms, z2, inv_2s2):
    del sq_norms, inv_2s2
    return ell_dots2(vals, cols, z2)


def ell_poly_row(vals, cols, sq_norms, z, inv_2s2, degree=3, coef0=1.0):
    del sq_norms
    return (inv_2s2 * ell_dots(vals, cols, z) + coef0) ** degree


def ell_poly_rows2(vals, cols, sq_norms, z2, inv_2s2, degree=3, coef0=1.0):
    del sq_norms
    return (inv_2s2 * ell_dots2(vals, cols, z2) + coef0) ** degree


_ELL_ROWS2 = {"rbf": ell_rbf_rows2, "linear": ell_linear_rows2,
              "poly": ell_poly_rows2}
_ELL_ROW = {"rbf": ell_rbf_row, "linear": ell_linear_row,
            "poly": ell_poly_row}


def get_ell_rows2(kernel: str) -> KernelRowFn:
    try:
        return _ELL_ROWS2[kernel]
    except KeyError:
        raise ValueError(f"unknown kernel {kernel!r}") from None


def get_ell_row(kernel: str) -> KernelRowFn:
    try:
        return _ELL_ROW[kernel]
    except KeyError:
        raise ValueError(f"unknown kernel {kernel!r}") from None


def ell_cross_kernel(kernel: str, Z: jax.Array, vals: jax.Array,
                     cols: jax.Array, sq_norms: jax.Array, inv_2s2: float,
                     max_gather: int = 1 << 24) -> jax.Array:
    """K(Z_j, x_i) for dense queries Z (nZ, d) against ELL samples. (nZ, M).

    Predict/objective-time helper — the ELL analogue of
    ``full_kernel_matrix``. The gather intermediate is (nZ, m_blk, K), so
    the sample axis is blocked to keep it under ``max_gather`` elements
    regardless of how large the SV set or the query block is.
    """
    nZ = Z.shape[0]
    M, K = vals.shape
    blk = max(1, min(M, max_gather // max(nZ * K, 1)))

    def dots_block(v, c):
        zg = jnp.take(Z, c, axis=1)                   # (nZ, blk, K)
        return jnp.einsum("mk,jmk->jm", v, zg)

    dots = jnp.concatenate(
        [dots_block(vals[s: s + blk], cols[s: s + blk])
         for s in range(0, M, blk)], axis=1)
    if kernel == "linear":
        return dots
    if kernel == "poly":
        return (inv_2s2 * dots + 1.0) ** 3
    zn = jnp.sum(Z * Z, axis=-1)
    d2 = zn[:, None] - 2.0 * dots + sq_norms[None, :]
    return jnp.exp(-jnp.maximum(d2, 0.0) * inv_2s2)


# --------------------------------------------------------------------------
# Row-provider layer: one protocol over every (storage format x backend)
# combination of kernel-row production. Chunk runners, reconstruction, and
# predict all consume providers instead of hand-rolling format branches,
# and the solver's LRU row cache (repro.core.rowcache) sits behind the same
# interface — a cache miss calls the exact provider kernel the cache-off
# path runs, which is what makes cache-on/cache-off bit-identical.


class RowProvider(Protocol):
    """Kernel-row production over a device data buffer (``DenseData`` /
    ``ELLData``). Methods are shard-local: none may issue a collective, so
    they are safe inside ``lax.cond`` (the cache's miss branch)."""

    def row(self, data, z: jax.Array) -> jax.Array:
        """K(z, buffer) — (M,)."""

    def rows2(self, data, z2: jax.Array) -> jax.Array:
        """Fused two-row K([z_up; z_low], buffer) — (M, 2), one HBM pass."""

    def matrix(self, data, Z: jax.Array) -> jax.Array:
        """K(Z_j, buffer_i) — (nZ, M); predict / reconstruction blocks."""

    def gamma_update(self, data, gamma, z2, coef2) -> jax.Array:
        """Fused Eq. 6: gamma + coef2[0]*K(z_up,.) + coef2[1]*K(z_low,.)."""

    def gamma_from_rows(self, gamma, rows, coef2) -> jax.Array:
        """Eq. 6 from already-produced rows (the cache-hit path)."""

    def diag(self, data) -> jax.Array:
        """K(x_i, x_i) for every buffer row — (M,) (wss2 curvature)."""

    def accumulate(self, data, Z: jax.Array, coef: jax.Array) -> jax.Array:
        """sum_i coef[i] * K(Z_j, buffer_i) — (nZ,) decision partials.

        The serving plane's one hot call (core/serve.py): Pallas backends
        fuse the coef contraction into the kernel-tile epilogue so the
        (nZ, M) matrix is never materialized; jnp backends compose
        ``matrix @ coef`` and are the parity oracle.
        """


@dataclasses.dataclass(frozen=True)
class _ProviderBase:
    """Frozen (hashable) so providers can be jit static arguments."""
    kernel: str
    inv_2s2: float

    def diag(self, data) -> jax.Array:
        sq = data.sq_norms
        if self.kernel == "rbf":
            return jnp.ones_like(sq)
        if self.kernel == "linear":
            return sq
        return (self.inv_2s2 * sq + 1.0) ** 3

    def gamma_from_rows(self, gamma, rows, coef2) -> jax.Array:
        return gamma + rows @ coef2

    def accumulate(self, data, Z, coef) -> jax.Array:
        return self.matrix(data, Z) @ coef


@dataclasses.dataclass(frozen=True)
class DenseRowProvider(_ProviderBase):
    def row(self, data, z):
        return _ROW[self.kernel](data.X, data.sq_norms, z, self.inv_2s2)

    def rows2(self, data, z2):
        return _ROWS2[self.kernel](data.X, data.sq_norms, z2, self.inv_2s2)

    def matrix(self, data, Z):
        return full_kernel_matrix(self.kernel, Z, data.X, self.inv_2s2)

    def gamma_update(self, data, gamma, z2, coef2):
        return gamma + self.rows2(data, z2) @ coef2


@dataclasses.dataclass(frozen=True)
class DensePallasRowProvider(DenseRowProvider):
    """Dense storage, Pallas backend (falls back per ``kernels.ops``).

    ``row`` stays the jnp GEMV — there is no single-row Pallas kernel, and
    the row path (wss2 selection / cache miss of one row) is O(M*d) GEMV
    that XLA already saturates.
    """

    def rows2(self, data, z2):
        from repro.kernels import ops
        return ops.kernel_rows2(self.kernel, data.X, data.sq_norms, z2,
                                self.inv_2s2)

    def gamma_update(self, data, gamma, z2, coef2):
        from repro.kernels import ops
        return ops.fused_gamma_update(self.kernel, data.X, data.sq_norms,
                                      gamma, z2, coef2, self.inv_2s2)

    def gamma_from_rows(self, gamma, rows, coef2):
        from repro.kernels import ops
        return ops.gamma_from_rows(gamma, rows, coef2)

    def accumulate(self, data, Z, coef):
        from repro.kernels import ops
        if self.kernel != "rbf":    # the accumulate kernel is RBF-only
            return super().accumulate(data, Z, coef)
        return ops.rbf_accumulate(data.X, data.sq_norms, coef, Z,
                                  self.inv_2s2)


@dataclasses.dataclass(frozen=True)
class ELLRowProvider(_ProviderBase):
    def row(self, data, z):
        return _ELL_ROW[self.kernel](data.vals, data.cols, data.sq_norms, z,
                                     self.inv_2s2)

    def rows2(self, data, z2):
        return _ELL_ROWS2[self.kernel](data.vals, data.cols, data.sq_norms,
                                       z2, self.inv_2s2)

    def matrix(self, data, Z):
        return ell_cross_kernel(self.kernel, Z, data.vals, data.cols,
                                data.sq_norms, self.inv_2s2)

    def gamma_update(self, data, gamma, z2, coef2):
        return gamma + self.rows2(data, z2) @ coef2


@dataclasses.dataclass(frozen=True)
class ELLPallasRowProvider(ELLRowProvider):
    def row(self, data, z):
        from repro.kernels import ops
        if self.kernel != "rbf":            # ELL Pallas kernels are RBF-only
            return super().row(data, z)
        return ops.ell_kernel_row(data.vals, data.cols, data.sq_norms, z,
                                  self.inv_2s2)

    def rows2(self, data, z2):
        from repro.kernels import ops
        if self.kernel != "rbf":
            return super().rows2(data, z2)
        return ops.ell_kernel_rows2(data.vals, data.cols, data.sq_norms, z2,
                                    self.inv_2s2)

    def gamma_update(self, data, gamma, z2, coef2):
        from repro.kernels import ops
        return ops.ell_fused_gamma_update(self.kernel, data.vals, data.cols,
                                          data.sq_norms, gamma, z2, coef2,
                                          self.inv_2s2)

    def gamma_from_rows(self, gamma, rows, coef2):
        from repro.kernels import ops
        return ops.gamma_from_rows(gamma, rows, coef2)

    def accumulate(self, data, Z, coef):
        from repro.kernels import ops
        if self.kernel != "rbf":
            return super().accumulate(data, Z, coef)
        return ops.ell_rbf_accumulate(data.vals, data.cols, data.sq_norms,
                                      coef, Z, self.inv_2s2)


def recon_block(provider: "RowProvider", sv_data, Zi: jax.Array,
                coef: jax.Array, never: jax.Array) -> jax.Array:
    """One Alg. 6 block: ``K(Zi, sv_data) @ coef`` — (nZ,) partial gammas.

    This is the ONE compute island both reconstruction backends run: the
    host-streaming path calls it per (SV block, query block) from a
    standalone jit, the device-mirror path calls it inside a
    ``lax.scan``/``fori_loop`` over mirror blocks. The structure is
    load-bearing for the bitwise device == host contract, exactly like
    ``rowcache.make_accessors``:

      * input/output ``optimization_barrier``s stop the exp/GEMV epilogue
        from being duplicated into context-dependent consumer fusions;
      * the compute sits inside a degenerate runtime-false ``lax.cond``
        (``never`` must be a *traced* False), because XLA CPU codegens a
        loop body's top-level region differently from a standalone
        executable (observed: ulp-level drift of the same block computed
        in a ``fori_loop`` vs top-level) while branch regions are
        outlined identically in both contexts.
    """
    sv_b, Zi_b, coef_b = jax.lax.optimization_barrier((sv_data, Zi, coef))
    compute = lambda: jax.lax.optimization_barrier(
        provider.matrix(sv_b, Zi_b) @ coef_b)
    zero = jnp.zeros((Zi.shape[0],), jnp.float32)
    return jax.lax.cond(never, lambda: zero, compute)


def row_via_rows2(provider: "RowProvider", data, z: jax.Array) -> jax.Array:
    """Context-stable single-row production: K(z, buffer) as column 0 of the
    fused two-row kernel applied to a *duplicated* query — (M,).

    Single-row GEMV computes are NOT context-stable on XLA CPU: the same
    ``provider.row`` drifts by ulps between loop-body and standalone
    contexts even behind barrier/cond islands (measured — this was the
    reason the wss2 row cache used to be invalidated wholesale at
    un-shrink). The rows2 GEMM *is* context-stable (the property every
    cache exactness contract already rests on), and its columns are
    position-symmetric (``ell_dots2`` is batch-major by construction;
    dense GEMM columns reduce independently), so ``rows2([z; z])[:, 0]``
    is a bit-stable single row at the cost of one redundant column. Both
    the in-loop wss2 miss path and the rewarm scan route through this one
    helper, which is what lets ``rowcache.regrow_cache`` carry the wss2
    cache across un-shrink exactly like wss1.
    """
    z2 = jax.lax.optimization_barrier(jnp.stack([z, z]))
    return jax.lax.optimization_barrier(provider.rows2(data, z2)[:, 0])


def make_provider(kernel: str, fmt: str = "dense", use_pallas: bool = False,
                  inv_2s2: float = 1.0) -> RowProvider:
    """Row provider for a (kernel, storage format, backend) combination —
    the single entry point the solver/parallel/reconstruct layers use."""
    if kernel not in _ROW:
        raise ValueError(f"unknown kernel {kernel!r}")
    if fmt == "dense":
        cls = DensePallasRowProvider if use_pallas else DenseRowProvider
    elif fmt == "ell":
        cls = ELLPallasRowProvider if use_pallas else ELLRowProvider
    else:
        raise ValueError(f"unknown data format {fmt!r}")
    return cls(kernel, float(inv_2s2))


def full_kernel_matrix(kernel: str, X: jax.Array, Z: jax.Array, inv_2s2: float,
                       block: int = 2048) -> jax.Array:
    """K(X_i, Z_j) — test/predict-time helper (never materialized in training;
    the paper's no-kernel-cache doctrine, Sec. 3.1.1)."""
    if kernel == "linear":
        return X @ Z.T
    if kernel == "poly":
        return (inv_2s2 * (X @ Z.T) + 1.0) ** 3
    xn = jnp.sum(X * X, axis=-1)
    zn = jnp.sum(Z * Z, axis=-1)
    d2 = xn[:, None] - 2.0 * (X @ Z.T) + zn[None, :]
    return jnp.exp(-jnp.maximum(d2, 0.0) * inv_2s2)
