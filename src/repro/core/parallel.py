"""Distributed SMO — the paper's Algorithms 3/4 on a JAX device mesh.

The outer Alg. 5 control flow (shrink -> compact -> reconstruct ->
un-shrink -> re-optimize) is NOT here: it lives in
:mod:`repro.core.driver` and is shared with the single-host solver. This
module provides the distributed *hooks* that driver consumes — shard_map
chunk runners, the ppermute reconstruction ring, and mesh placement
(including the output-sharding pins the jitted device-compaction step uses
to re-gather sharded buffers and the sharded cache value table in place).

Mapping from the paper's MPI/Global-Arrays design (DESIGN.md §2):

  * each mesh shard owns a contiguous, balanced block of samples
    (X, y, alpha, gamma, active) — the Global-Arrays distribution;
  * MPI_Bcast of (x_up, x_low)  ->  one fused ``lax.all_gather`` of per-shard
    candidate payloads [beta_up, beta_low, alpha_up, y_up, alpha_low, y_low,
    x_up_row, x_low_row] (p x (2d+6) floats) + replicated argmin/argmax —
    every device then holds the winning rows, same O(log p) tree cost;
  * MPI_Allreduce of (beta_up, beta_low) -> folded into the same all_gather;
  * shrinkitercounter allreduce (Alg. 4)  -> ``lax.psum`` of local active
    counts (one scalar);
  * gamma update (Eq. 6) runs shard-locally with zero communication.

So the per-iteration communication is exactly one all_gather(p, 2d+6) + one
psum(1) — two collectives, matching the paper's two (bcast + allreduce).

Gradient reconstruction (Alg. 6) is a ring: (X_shard, coef_shard) blocks
rotate via ``lax.ppermute`` while each shard accumulates K(X_stale, block) @
coef partial sums — p steps, compute/comm overlappable, no kernel cache.
The ring runs in both mirror modes on the same executable: ``mirror='host'``
builds its inputs in host numpy (the parity oracle), the device mirror
derives them on device (per-position alpha/coef from the (n,) masters, the
SV-masked payload masked out of the mirror rows) and scatters stale outputs
straight into the donated gamma master — bit-identical arrays either way,
because both use the mirror's balanced buffer layout and the store-level
``sq_rows`` provenance.
"""
from __future__ import annotations

import functools
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import dataplane, driver, kernel_fns
from repro.core import mirror as mirror_mod
from repro.core import reconstruct, rowcache, smo, solver
from repro.core import util
from repro.data import sparse as spfmt
from repro.launch.mesh import shard_map_compat

AXIS = "shards"


def data_mesh(n_devices: Optional[int] = None, axis: str = AXIS) -> Mesh:
    from repro.launch.mesh import make_mesh
    devs = jax.devices()[: n_devices or len(jax.devices())]
    return make_mesh((len(devs),), (axis,), devices=devs)


def make_parallel_chunk_runner(mesh: Mesh, kernel: str, C: float,
                               inv_2s2: float, shrink_interval: int,
                               axis: str = AXIS, use_pallas: bool = False,
                               fmt: str = "dense", n_features: int = 0,
                               selection: str = "wss1",
                               cache_slots: int = 0,
                               cache_policy: str = "lru"):
    """shard_map fused-epoch SMO runner — the distributed twin of
    ``smo.make_chunk_runner``: one dispatch runs up to ``k`` segments of up
    to ``chunk_iters`` iterations, evaluates the hard exits and the
    compaction predicate on device between segments (loop control fully
    replicated, so collective trip counts stay uniform), and returns
    ``(state, cache, EpochSummary)`` with the (p,) ELL shard extents
    computed under the ``need_compact`` cond in the wrapping jit. All
    schedule scalars are traced. State scalars are replicated; arrays
    sharded.

    ``fmt='ell'`` consumes block-ELL shards (vals, cols, sq); candidate rows
    are densified locally before the all_gather so the collective payload
    stays the paper's (p, 2d+6) bcast shape, and the shard-local gamma sweep
    runs on the sparse stream. All row production goes through the
    row-provider layer (``kernel_fns.make_provider``) on a locally
    reassembled ``DenseData``/``ELLData`` view, so the gamma path here and
    in the single-host runner are the same provider calls.

    ``selection='wss2'`` threads second-order pair selection through the
    mesh: i_up and the termination betas come from the usual fused
    candidate exchange, then the i_up row is produced shard-locally, wss2
    scores are maximized per shard, and ONE extra all_gather of
    (score, gamma, alpha, y[, gid], x_row) elects i_low globally — so wss2
    costs 2 all_gathers + 1 psum per iteration vs wss1's 1 + 1 (the paper's
    two-collective budget holds for its own wss1 algorithm).

    ``cache_slots`` > 0 threads the LRU kernel-row cache through the loop.
    The (slots, M) value table is sharded over the mesh on the buffer axis
    — each shard caches exactly its own M_local row segments — while the
    tag/stamp tables replicate: lookups key on *global* sample ids, which
    ride the candidate payload as two bitcast lanes (p x (2d+8) instead of
    p x (2d+6); still one collective), so every shard takes identical
    hit/miss branches with zero extra collectives.

    The ELL lane budget K is *not* closed over: it is a trace dimension
    (``vals_l.shape[1]``), so adaptive-K recompaction re-traces this runner
    once per K bucket (the driver buckets K to power-of-two lanes precisely
    to bound that). Per-shard K is lane-rounded host-side at buffer-fill
    time (``FitStats.shard_K``); the device array itself is padded to
    max(shard_K) because shard_map and the collectives need one uniform
    shape — the paper's per-rank MPI buffers are ragged, ours are ragged
    only in which lanes carry nonzeros.
    """
    kself = kernel_fns.self_kernel(kernel)
    row1 = kernel_fns.get_row(kernel)
    provider = kernel_fns.make_provider(kernel, fmt, use_pallas, inv_2s2)
    cached = cache_slots > 0
    n_data = 3 if fmt == "ell" else 2
    gl = 2 if cached else 0          # gid lanes in the candidate payload

    def local_chunk(*args):
        if fmt == "ell":
            vals_l, cols_l, sq_l = args[:3]
        else:
            X_l, sq_l = args[:2]
        rest = args[n_data:]
        gid_l = None
        cache0 = None
        if cached:
            gid_l, rest = rest[0], rest[1:]
        (y_l, alpha_l, gamma_l, active_l,
         step0, next_shrink0, n_shrinks0) = rest[:7]
        rest = rest[7:]
        if cached:
            cache0, rest = rest[0], rest[1:]
        tol, k, chunk_iters, max_iters, compact_lt, mper_lo = rest

        if fmt == "ell":
            ldata = dataplane.ELLData(vals_l, cols_l, sq_l, n_features,
                                      gid_l)
            d = n_features
        else:
            ldata = dataplane.DenseData(X_l, sq_l, gid_l)
            d = X_l.shape[1]
        p = mesh.shape[axis]          # static (lax.axis_size is JAX >= 0.6)
        me = lax.axis_index(axis)
        if selection == "wss2":
            kdiag_l = provider.diag(ldata)

        # Row access with structural parity between the cached and uncached
        # executables — shared with the single-host runner because the
        # barrier/cond structure is load-bearing for the bitwise exactness
        # contract (see rowcache.make_accessors).
        get_row1, get_rows2 = rowcache.make_accessors(
            provider, ldata, cached, tol < 0.0, cache_policy)

        def gather_select(gamma_l, alpha_l, active_l):
            """Local Eq. 8 + fused candidate exchange. Returns replicated
            (b_up, b_low, payload rows/scalars) and my local candidate idx."""
            b_up_l, j_up, b_low_l, j_low = smo.select_pair(
                gamma_l, alpha_l, y_l, active_l, C)
            parts = [jnp.stack([b_up_l, b_low_l, alpha_l[j_up], y_l[j_up],
                                alpha_l[j_low], y_l[j_low]])]
            if cached:               # global row ids ride as bitcast lanes
                parts.append(lax.bitcast_convert_type(
                    jnp.stack([gid_l[j_up], gid_l[j_low]]), jnp.float32))
            parts += [ldata.dense_row(j_up), ldata.dense_row(j_low)]
            pay = jnp.concatenate(parts)                   # (6 + gl + 2d,)
            pays = lax.all_gather(pay, axis)               # (p, 6 + gl + 2d)
            k_up = jnp.argmin(pays[:, 0])
            k_low = jnp.argmax(pays[:, 1])
            off = 6 + gl
            sel = dict(
                beta_up=pays[k_up, 0], beta_low=pays[k_low, 1],
                a_up=pays[k_up, 2], y_up=pays[k_up, 3],
                a_low=pays[k_low, 4], y_low=pays[k_low, 5],
                x_up=pays[k_up, off: off + d], x_low=pays[k_low, off + d:],
                k_up=k_up, k_low=k_low, j_up=j_up, j_low=j_low)
            if cached:
                sel["gid_up"] = lax.bitcast_convert_type(
                    pays[k_up, 6], jnp.int32)
                sel["gid_low"] = lax.bitcast_convert_type(
                    pays[k_low, 7], jnp.int32)
            return sel

        def body(carry):
            (alpha_l, gamma_l, active_l, cache, sel, step, next_shrink,
             n_shrinks, conv, stalled) = carry
            x_up = sel["x_up"]
            k_uu = kself(x_up, inv_2s2)

            if selection == "wss2":
                # second-order i_low: i_up row shard-locally, then one
                # extra candidate exchange electing the best-scored shard.
                # The candidate's K(up, cand) — its entry of the selection
                # row — rides the payload, so the update step reuses the
                # exact value the scores priced the pair with instead of
                # recomputing the O(d) dot (still one collective).
                row_up_l, cache = get_row1(
                    cache, sel["gid_up"] if cached else None, x_up)
                scores_l = smo.wss2_scores(
                    gamma_l, alpha_l, y_l, active_l, C, sel["beta_up"],
                    row_up_l, kdiag_l, k_uu)
                j2 = jnp.argmax(scores_l)
                parts2 = [jnp.stack([scores_l[j2], gamma_l[j2],
                                     alpha_l[j2], y_l[j2], row_up_l[j2]])]
                if cached:
                    parts2.append(lax.bitcast_convert_type(
                        gid_l[j2][None], jnp.float32))
                parts2.append(ldata.dense_row(j2))
                pays2 = lax.all_gather(jnp.concatenate(parts2), axis)
                k_low = jnp.argmax(pays2[:, 0])
                off2 = 5 + (1 if cached else 0)
                g_low = pays2[k_low, 1]
                a_low = pays2[k_low, 2]
                y_low = pays2[k_low, 3]
                k_ul2 = pays2[k_low, 4]
                x_low = pays2[k_low, off2:]
                gid_low = (lax.bitcast_convert_type(pays2[k_low, 5],
                                                    jnp.int32)
                           if cached else None)
                j_low = j2
            else:
                g_low = sel["beta_low"]
                a_low, y_low, x_low = sel["a_low"], sel["y_low"], sel["x_low"]
                k_low, j_low = sel["k_low"], sel["j_low"]
                gid_low = sel.get("gid_low")

            x2 = jnp.stack([x_up, x_low])
            if selection == "wss2":
                k_ul = k_ul2          # selection-row reuse (see above)
            else:
                # replicated O(d); barrier-isolated for the exactness
                # contract (see smo.make_chunk_runner)
                xu_b, xl_b = lax.optimization_barrier((x_up, x_low))
                k_ul = lax.optimization_barrier(
                    row1(xl_b[None, :], jnp.sum(xl_b * xl_b)[None],
                         xu_b, inv_2s2)[0])
            a_up_new, a_low_new = smo.pair_update(
                sel["a_up"], a_low, sel["y_up"], y_low,
                sel["beta_up"], g_low, k_ul,
                k_uu, kself(x_low, inv_2s2), C)
            d_up = a_up_new - sel["a_up"]
            d_low = a_low_new - a_low
            stalled = (jnp.abs(d_up) < smo._TAU) & (jnp.abs(d_low) < smo._TAU)

            # owner shards write the new alphas back into their block
            alpha_l = jnp.where(me == sel["k_up"],
                                alpha_l.at[sel["j_up"]].set(a_up_new), alpha_l)
            alpha_l = jnp.where(me == k_low,
                                alpha_l.at[j_low].set(a_low_new), alpha_l)
            coef2 = jnp.stack([sel["y_up"] * d_up, y_low * d_low])
            if selection == "wss2":
                row_low_l, cache = get_row1(cache, gid_low, x_low)
                gamma_l = gamma_l + coef2[0] * row_up_l + coef2[1] * row_low_l
            elif use_pallas and not cached:
                # fused one-HBM-pass Pallas kernel; no exactness contract
                # with the (rows2 + FMA) cached path on this backend
                gamma_l = provider.gamma_update(ldata, gamma_l, x2, coef2)
            else:
                gid2 = (jnp.stack([sel["gid_up"], sel["gid_low"]])
                        if cached else None)
                rows_l, cache = get_rows2(cache, gid2, x2)  # (m_l, 2)
                gamma_l = provider.gamma_from_rows(gamma_l, rows_l, coef2)

            step1 = step + 1
            do_shrink = (shrink_interval > 0) & (step1 >= next_shrink)
            active_l = lax.cond(
                do_shrink,
                lambda: smo.shrink_rule(gamma_l, alpha_l, y_l, active_l,
                                        sel["beta_up"], sel["beta_low"], C),
                lambda: active_l)
            # Alg. 4 line 12: allreduce of local active counts
            n_active = lax.psum(jnp.sum(active_l.astype(jnp.int32)), axis)
            interval = jnp.maximum(
                jnp.minimum(jnp.int32(shrink_interval), n_active), 1)
            next_shrink = jnp.where(do_shrink, step1 + interval, next_shrink)
            n_shrinks = n_shrinks + do_shrink.astype(jnp.int32)

            sel = gather_select(gamma_l, alpha_l, active_l)
            conv = sel["beta_up"] + tol >= sel["beta_low"]
            return (alpha_l, gamma_l, active_l, cache, sel, step1,
                    next_shrink, n_shrinks, conv, stalled)

        m_total = sq_l.shape[0] * p          # global buffer rows (static)

        def run_segment(alpha_l, gamma_l, active_l, cache, step,
                        next_shrink, n_shrinks):
            # Segment entry == legacy dispatch entry: re-elect the global
            # working set (replicated — all shards agree) and clear the
            # stall latch. Loop control is replicated throughout, so the
            # collectives inside see uniform trip counts on every shard.
            sel0 = gather_select(gamma_l, alpha_l, active_l)
            conv0 = sel0["beta_up"] + tol >= sel0["beta_low"]
            start = step
            lim = jnp.minimum(chunk_iters, jnp.maximum(1, max_iters - start))

            def cond(carry):
                (_, _, _, _, _, step, _, _, conv, stalled) = carry
                return (~conv) & (~stalled) & (step - start < lim)

            carry = (alpha_l, gamma_l, active_l, cache, sel0, step,
                     next_shrink, n_shrinks, conv0, jnp.bool_(False))
            return lax.while_loop(cond, body, carry)

        def epoch_cond(carry):
            segs, done = carry[11], carry[13]
            return (~done) & (segs < k)

        def epoch_body(carry):
            (alpha_l, gamma_l, active_l, cache, step, next_shrink,
             n_shrinks, _, _, _, _, segs, min_act, _, _, _) = carry
            (alpha_l, gamma_l, active_l, cache, sel, step, next_shrink,
             n_shrinks, conv, stalled) = run_segment(
                alpha_l, gamma_l, active_l, cache, step, next_shrink,
                n_shrinks)
            n_act = lax.psum(jnp.sum(active_l.astype(jnp.int32)), axis)
            min_act = jnp.minimum(min_act, n_act)
            hard = conv | stalled | (step >= max_iters)
            if shrink_interval > 0:
                # device twin of the host compaction rule — see
                # smo.make_chunk_runner; exact integer arithmetic
                m_per_new = util.bucket_pow2_device(
                    (n_act + p - 1) // p, mper_lo)
                need_c = ((~hard) & (n_act < compact_lt)
                          & (m_per_new * p < m_total))
            else:
                need_c = jnp.bool_(False)
            return (alpha_l, gamma_l, active_l, cache, step, next_shrink,
                    n_shrinks, sel["beta_up"], sel["beta_low"], conv,
                    stalled, segs + 1, min_act, hard | need_c, need_c,
                    n_act)

        carry = (alpha_l, gamma_l, active_l, cache0, step0, next_shrink0,
                 n_shrinks0, jnp.float32(-1.0), jnp.float32(1.0),
                 jnp.bool_(False), jnp.bool_(False), jnp.int32(0),
                 jnp.int32(jnp.iinfo(jnp.int32).max), jnp.bool_(False),
                 jnp.bool_(False), jnp.int32(0))
        (alpha_l, gamma_l, active_l, cache, step, next_shrink, n_shrinks,
         b_up, b_low, conv, stalled, segs, min_act, _, need_c,
         n_act) = lax.while_loop(epoch_cond, epoch_body, carry)
        out = (alpha_l, gamma_l, active_l, b_up, b_low, step, next_shrink,
               n_shrinks, conv, stalled, segs, min_act, need_c, n_act)
        if cached:
            out += (cache,)
        return out

    sharded = P(axis)
    rep = P()
    data_specs = ((P(axis, None), P(axis, None), sharded) if fmt == "ell"
                  else (P(axis, None), sharded))
    # tag/stamp/counter tables replicate (all shards take identical cache
    # decisions — lookups key on replicated global ids); only the value
    # table is sharded, on the buffer axis, so each shard caches its own
    # M_local row segments.
    cache_spec = rowcache.RowCache(
        tags=rep, vals=P(None, axis), stamp=rep, seg=rep, tick=rep,
        hits=rep, misses=rep)
    in_specs = data_specs
    if cached:
        in_specs += (sharded,)                 # gids
    in_specs += (sharded,) * 4 + (rep,) * 3
    if cached:
        in_specs += (cache_spec,)
    in_specs += (rep,) * 6        # tol, k, chunk_iters, max_iters,
                                  # compact_lt, mper_lo
    out_specs = (sharded, sharded, sharded) + (rep,) * 11
    if cached:
        out_specs += (cache_spec,)
    mapped = shard_map_compat(local_chunk, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs)
    p_mesh = mesh.shape[axis]

    @jax.jit
    def epoch(*args):
        out = mapped(*args)
        active, need_c, n_act = out[2], out[12], out[13]
        if fmt == "ell" and shrink_interval > 0:
            # (p,) surviving extents ride the summary — integer-exact, so
            # they match the single-host runner's values bit-for-bit
            shard_ext = lax.cond(
                need_c,
                lambda: dataplane.ell_shard_extents_dyn(
                    args[0], active, n_act, p_mesh),
                lambda: jnp.zeros((p_mesh,), jnp.int32))
        else:
            shard_ext = jnp.zeros((p_mesh,), jnp.int32)
        return out + (shard_ext,)

    def run_epoch(data, y, state: smo.SMOState, cache, tol, k, chunk_iters,
                  max_iters, compact_lt, mper_lo):
        dargs = ((data.vals, data.cols, data.sq_norms) if fmt == "ell"
                 else (data.X, data.sq_norms))
        if cached:
            dargs += (data.gids,)
        args = dargs + (y, state.alpha, state.gamma, state.active,
                        state.step, state.next_shrink, state.n_shrinks)
        if cached:
            args += (cache,)
        args += (tol, jnp.int32(k), jnp.int32(chunk_iters),
                 jnp.int32(max_iters), jnp.int32(compact_lt),
                 jnp.int32(mper_lo))
        out = epoch(*args)
        (alpha, gamma, active, b_up, b_low, step, next_shrink, n_shrinks,
         conv, stalled, segs, min_act, need_c, n_act) = out[:14]
        cache_out = out[14] if cached else None
        shard_ext = out[-1]
        summ = smo.EpochSummary(
            step=step, segs=segs, n_active=n_act, min_active=min_act,
            n_shrinks=n_shrinks, converged=conv, stalled=stalled,
            need_compact=need_c,
            cache_hits=cache_out.hits if cached else jnp.int32(0),
            cache_misses=cache_out.misses if cached else jnp.int32(0),
            shard_ext=shard_ext)
        return state._replace(
            alpha=alpha, gamma=gamma, active=active, beta_up=b_up,
            beta_low=b_low, step=step, next_shrink=next_shrink,
            n_shrinks=n_shrinks, converged=conv, stalled=stalled), \
            cache_out, summ

    return run_epoch


def make_parallel_multi_runner(mesh: Mesh, kernel: str, inv_2s2: float,
                               shrink_interval: int, axis: str = AXIS,
                               fmt: str = "dense", n_features: int = 0,
                               shrink_min_interval: int = 1):
    """shard_map twin of :func:`repro.core.multi.make_multi_runner`: K
    problems batched on a leading lane axis, data sharded over the mesh,
    problems replicated. The per-iteration communication stays the paper's
    two-collective budget *independent of K*: the per-problem candidate
    payloads [beta_up, beta_low, alpha_up, y_up, alpha_low, y_low, x_up_row,
    x_low_row] are coalesced into ONE stacked ``lax.all_gather`` of
    (K, 6 + 2d) floats — K MPI_Bcasts fused into one collective — plus one
    (K,) psum of local active counts for the shrink interval clamp.

    Scope (the driver enforces): wss1 selection, cache off, dense/ELL, no
    Pallas. Shrinking is per-problem *logical* only — ``need_compact`` is
    pinned False, the buffer stays full-resident (the single-host batched
    runner owns the union-compaction geometry; here every shard keeps its
    block and the (K,) active counts only drive the shrink cadence).

    Per-problem update math is python-unrolled onto scalar lanes exactly
    like the single-host batched runner (a (K,) vectorized f32 chain is
    FMA-contracted differently at K >= 4 — see make_multi_runner), and the
    shard-local gamma sweep is the stacked (m_local, 2K) provider GEMM in
    the same barrier/degenerate-cond island. For ``fmt='dense'`` this
    makes the sharded trajectory BITWISE equal to the single-host batched
    driver, per problem. For ``fmt='ell'`` the guarantee is weaker —
    deterministic per executable, but up to ~1 ulp/update vs single-host:
    the fusion pass may split the sealed O(d) row islands' reductions
    differently in the shard-local vs full-buffer modules, which changes
    their contraction (see test_parallel_multi_batched_equals_single_host
    for the pinned contract).

    Returns ``run_epoch`` with the single-host batched signature
    (``cache`` must be None; ``compact_lt``/``mper_lo`` are accepted and
    ignored).
    """
    from repro.core import multi as multi_mod

    row1 = kernel_fns.get_row(kernel)
    kself = kernel_fns.self_kernel(kernel)
    provider = kernel_fns.make_provider(kernel, fmt, False, inv_2s2)
    n_data = 3 if fmt == "ell" else 2
    p = mesh.shape[axis]

    def local_chunk(*args):
        if fmt == "ell":
            vals_l, cols_l, sq_l = args[:3]
            ldata = dataplane.ELLData(vals_l, cols_l, sq_l, n_features)
            d = n_features
        else:
            X_l, sq_l = args[:2]
            ldata = dataplane.DenseData(X_l, sq_l)
            d = X_l.shape[1]
        (ystk_l, alpha_l, gamma_l, active_l, step0, next_shrink0,
         n_shrinks0, live, thr0, thr1, Cv, tol, k, chunk_iters,
         max_iters) = args[n_data:]
        Kp = ystk_l.shape[0]
        kk = jnp.arange(Kp)
        me = lax.axis_index(axis)
        never = tol[0] < 0.0                     # traced False (runtime)

        def rows_dense(idx):                     # (K,) local -> (K, d)
            return jnp.stack([ldata.dense_row(idx[i]) for i in range(Kp)])

        def kself_v(z):                          # (K, d) -> (K,)
            return jnp.stack([jnp.asarray(kself(z[i], inv_2s2), jnp.float32)
                              for i in range(Kp)])

        def stacked_rows(zq, ncols):
            # same production island as the single-host stacked GEMM — the
            # shard's rows of K(X, Z) are row-independent dots, so each
            # block matches the single-host bits for those rows
            zero = jnp.zeros(sq_l.shape + (ncols,), jnp.float32)
            compute = lambda: lax.optimization_barrier(
                provider.rows2(ldata, lax.optimization_barrier(zq)))
            return lax.cond(never, lambda: zero, compute)

        def gather_select(gamma_l, alpha_l, active_l):
            """Local per-problem Eq. 8 + ONE stacked candidate exchange.
            Returns replicated per-problem winners."""
            b_up_l, j_up, b_low_l, j_low = smo.select_pair_multi(
                gamma_l, alpha_l, ystk_l, active_l, thr0, thr1)
            pay = jnp.concatenate([
                jnp.stack([b_up_l, b_low_l, alpha_l[kk, j_up],
                           ystk_l[kk, j_up], alpha_l[kk, j_low],
                           ystk_l[kk, j_low]], axis=1),      # (K, 6)
                rows_dense(j_up), rows_dense(j_low)], axis=1)
            pays = lax.all_gather(pay, axis)                 # (p, K, 6+2d)
            k_up = jnp.argmin(pays[:, :, 0], axis=0)         # (K,)
            k_low = jnp.argmax(pays[:, :, 1], axis=0)
            return dict(
                beta_up=pays[k_up, kk, 0], beta_low=pays[k_low, kk, 1],
                a_up=pays[k_up, kk, 2], y_up=pays[k_up, kk, 3],
                a_low=pays[k_low, kk, 4], y_low=pays[k_low, kk, 5],
                x_up=pays[k_up, kk, 6: 6 + d],
                x_low=pays[k_low, kk, 6 + d:],
                k_up=k_up, k_low=k_low, j_up=j_up, j_low=j_low)

        def run_segment(alpha_l, gamma_l, active_l, step, next_shrink,
                        n_shrinks, conv_in, stall_in, jiters):
            sel0 = gather_select(gamma_l, alpha_l, active_l)
            conv0 = sel0["beta_up"] + tol >= sel0["beta_low"]
            start = step
            lim = jnp.minimum(chunk_iters,
                              jnp.maximum(1, max_iters - start))   # (K,)

            def running(step, conv, stalled):
                return (live & (~conv) & (~stalled) & (step - start < lim))

            def cond(carry):
                (_, _, _, _, step, _, _, conv, stalled, _) = carry
                return jnp.any(running(step, conv, stalled))

            def body(carry):
                (alpha_l, gamma_l, active_l, sel, step, next_shrink,
                 n_shrinks, conv, stalled, j) = carry
                run = running(step, conv, stalled)             # (K,)
                x_up, x_low = sel["x_up"], sel["x_low"]
                k_uu = kself_v(x_up)
                k_ll = kself_v(x_low)
                # per-problem O(d) scalar islands (see make_multi_runner)
                kul = []
                for i in range(Kp):
                    xu_b, xl_b = lax.optimization_barrier(
                        (x_up[i], x_low[i]))
                    kul.append(lax.optimization_barrier(
                        row1(xl_b[None, :], jnp.sum(xl_b * xl_b)[None],
                             xu_b, inv_2s2)[0]))
                k_ul = jnp.stack(kul)
                ups, lows = [], []
                for i in range(Kp):
                    u, l = smo.pair_update_multi(
                        sel["a_up"][i], sel["a_low"][i], sel["y_up"][i],
                        sel["y_low"][i], sel["beta_up"][i],
                        sel["beta_low"][i], k_ul[i], k_uu[i], k_ll[i],
                        Cv[i])
                    ups.append(u)
                    lows.append(l)
                a_up_new = jnp.stack(ups)
                a_low_new = jnp.stack(lows)
                d_up = a_up_new - sel["a_up"]
                d_low = a_low_new - sel["a_low"]
                stall_new = ((jnp.abs(d_up) < smo._TAU)
                             & (jnp.abs(d_low) < smo._TAU))
                stalled = jnp.where(run, stall_new, stalled)

                # owner shards write the new alphas into their block; the
                # up write lands before the low write (j_up == j_low ties
                # resolve exactly like the scalar .at chain)
                up_here = run & (me == sel["k_up"])
                alpha_l = alpha_l.at[kk, sel["j_up"]].set(
                    jnp.where(up_here, a_up_new,
                              alpha_l[kk, sel["j_up"]]))
                low_here = run & (me == sel["k_low"])
                alpha_l = alpha_l.at[kk, sel["j_low"]].set(
                    jnp.where(low_here, a_low_new,
                              alpha_l[kk, sel["j_low"]]))

                coef2 = jnp.stack([sel["y_up"] * d_up,
                                   sel["y_low"] * d_low], axis=1)
                z_all = jnp.stack([x_up, x_low], axis=1).reshape(2 * Kp, -1)
                rows = stacked_rows(z_all, 2 * Kp)         # (m_l, 2K)
                gamma_new = jnp.stack([
                    provider.gamma_from_rows(
                        gamma_l[i], rows[:, 2 * i: 2 * i + 2], coef2[i])
                    for i in range(Kp)])
                gamma_l = jnp.where(run[:, None], gamma_new, gamma_l)

                step1 = step + run.astype(jnp.int32)
                if shrink_interval > 0:
                    do_shrink = run & (step1 >= next_shrink)
                    active_l = lax.cond(
                        jnp.any(do_shrink),
                        lambda: jnp.where(
                            do_shrink[:, None],
                            smo.shrink_rule_multi(
                                gamma_l, alpha_l, ystk_l, active_l,
                                sel["beta_up"], sel["beta_low"],
                                thr0, thr1),
                            active_l),
                        lambda: active_l)
                else:
                    do_shrink = jnp.zeros((Kp,), bool)
                # Alg. 4 line 12, K lanes in one psum
                n_active = lax.psum(
                    jnp.sum(active_l.astype(jnp.int32), axis=1), axis)
                interval = jnp.maximum(
                    jnp.minimum(jnp.int32(shrink_interval), n_active),
                    shrink_min_interval)
                next_shrink = jnp.where(do_shrink, step1 + interval,
                                        next_shrink)
                n_shrinks = n_shrinks + do_shrink.astype(jnp.int32)

                sel2 = gather_select(gamma_l, alpha_l, active_l)
                sel2 = {key: jnp.where(
                            run.reshape((Kp,) + (1,) * (v.ndim - 1)), v,
                            sel[key])
                        for key, v in sel2.items()}
                conv = jnp.where(run,
                                 sel2["beta_up"] + tol >= sel2["beta_low"],
                                 conv)
                return (alpha_l, gamma_l, active_l, sel2, step1,
                        next_shrink, n_shrinks, conv, stalled, j + 1)

            carry = (alpha_l, gamma_l, active_l, sel0, step, next_shrink,
                     n_shrinks, conv0, jnp.zeros((Kp,), bool), jiters)
            (alpha_l, gamma_l, active_l, sel, step, next_shrink, n_shrinks,
             conv, stalled, jiters) = lax.while_loop(cond, body, carry)
            return (alpha_l, gamma_l, active_l, sel["beta_up"],
                    sel["beta_low"], step, next_shrink, n_shrinks, conv,
                    stalled, jiters)

        def epoch_cond(carry):
            segs, done = carry[11], carry[12]
            return (~done) & (segs < k)

        def epoch_body(carry):
            (alpha_l, gamma_l, active_l, _, _, step, next_shrink, n_shrinks,
             conv, stalled, jiters, segs, _) = carry
            (alpha_l, gamma_l, active_l, b_up, b_low, step, next_shrink,
             n_shrinks, conv, stalled, jiters) = run_segment(
                alpha_l, gamma_l, active_l, step, next_shrink, n_shrinks,
                conv, stalled, jiters)
            runmask = live & (~conv) & (~stalled) & (step < max_iters)
            hard = ~jnp.any(runmask)
            return (alpha_l, gamma_l, active_l, b_up, b_low, step,
                    next_shrink, n_shrinks, conv, stalled, jiters,
                    segs + 1, hard)

        carry0 = (alpha_l, gamma_l, active_l,
                  jnp.full((Kp,), -1.0, jnp.float32),
                  jnp.full((Kp,), 1.0, jnp.float32),
                  step0, next_shrink0, n_shrinks0,
                  jnp.zeros((Kp,), bool), jnp.zeros((Kp,), bool),
                  jnp.int32(0), jnp.int32(0), jnp.bool_(False))
        (alpha_l, gamma_l, active_l, b_up, b_low, step, next_shrink,
         n_shrinks, conv, stalled, jiters, segs, _) = lax.while_loop(
            epoch_cond, epoch_body, carry0)
        n_act = lax.psum(jnp.sum(active_l.astype(jnp.int32), axis=1), axis)
        act_live = active_l & live[:, None]
        n_union = lax.psum(
            jnp.sum(jnp.any(act_live, axis=0)).astype(jnp.int32), axis)
        return (alpha_l, gamma_l, active_l, b_up, b_low, step, next_shrink,
                n_shrinks, conv, stalled, segs, jiters, n_act, n_union)

    sharded2 = P(None, axis)             # (K, m) problem-stacked buffers
    rep = P()
    data_specs = ((P(axis, None), P(axis, None), P(axis)) if fmt == "ell"
                  else (P(axis, None), P(axis)))
    in_specs = data_specs + (sharded2,) * 4 + (rep,) * 11
    out_specs = (sharded2, sharded2, sharded2) + (rep,) * 11
    mapped = shard_map_compat(local_chunk, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs)
    epoch = jax.jit(mapped)

    def run_epoch(data, ystk, state, cache, thr0, thr1, Cv, tol, k,
                  chunk_iters, max_iters, compact_lt, mper_lo):
        assert cache is None, "parallel batched runner is cache-off"
        dargs = ((data.vals, data.cols, data.sq_norms) if fmt == "ell"
                 else (data.X, data.sq_norms))
        out = epoch(*dargs, ystk, state.alpha, state.gamma, state.active,
                    state.step, state.next_shrink, state.n_shrinks,
                    state.live, thr0, thr1, Cv, tol, jnp.int32(k),
                    jnp.int32(chunk_iters), jnp.int32(max_iters))
        (alpha, gamma, active, b_up, b_low, step, next_shrink, n_shrinks,
         conv, stalled, segs, jiters, n_act, n_union) = out
        summ = multi_mod.MultiEpochSummary(
            step=step, segs=segs, joint_iters=jiters, n_active=n_act,
            n_active_union=n_union, n_shrinks=n_shrinks, converged=conv,
            stalled=stalled, need_compact=jnp.bool_(False),
            cache_hits=jnp.int32(0), cache_misses=jnp.int32(0))
        return state._replace(
            alpha=alpha, gamma=gamma, active=active, beta_up=b_up,
            beta_low=b_low, step=step, next_shrink=next_shrink,
            n_shrinks=n_shrinks, converged=conv, stalled=stalled), \
            None, summ

    return run_epoch


def make_ring_reconstructor(mesh: Mesh, kernel: str, inv_2s2: float,
                            axis: str = AXIS, row_block: int = 4096,
                            fmt: str = "dense", n_features: int = 0):
    """Distributed Alg. 6: ring-rotate each shard's sample block + coef;
    every shard accumulates kernel-block @ coef partials for its stale rows.

    ``fmt='dense'`` rotates (X, coef, sq) — d+2 floats per row. ``fmt='ell'``
    takes *two* sparse payloads: an own-side (vals, cols) block at the full
    set's adaptive K (densified locally for the stale rows), and a ring
    payload (rvals, rcols) that holds only support-vector rows (coef != 0;
    everything else zeroed by the caller) at the *SV set's* lane-rounded K.
    Only the ring payload rotates, so inter-device traffic is 2*K_sv + 2
    floats per row — it shrinks with both the density factor (the paper's
    Fig. 1b argument applied to communication) and the adaptive lane budget
    of the current support set. Zeroed non-SV rows are exact: their coef is
    0, so their kernel column contributes nothing regardless of values.
    Both K's are trace dimensions; jit re-specializes per shape bucket.
    """
    n_data = 4 if fmt == "ell" else 1

    def block_dense(*parts):
        """Sample block as dense rows: identity for dense, scatter for ELL."""
        if fmt == "dense":
            return parts[0]
        vals, cols = parts
        m = vals.shape[0]
        return jnp.zeros((m, n_features), jnp.float32) \
            .at[jnp.arange(m)[:, None], cols].add(vals)

    def local(*args):
        if fmt == "ell":
            own = args[:2]                        # (vals, cols) @ K_own
            ring0 = args[2:4]                     # SV-only (rvals, rcols) @ K_sv
        else:
            own = args[:1]                        # X doubles as ring payload
            ring0 = own
        y_l, alpha_l, gamma_l, stale_l = args[n_data:]
        p = mesh.shape[axis]                      # static axis size
        coef_l = alpha_l * y_l                    # zero where alpha == 0
        m_l = own[0].shape[0]
        # ring-side sq from the ring payload: exact for coef != 0 rows,
        # irrelevant (0-weighted) for the zeroed non-SV rows.
        sq_ring = jnp.sum(ring0[0] * ring0[0], axis=-1)
        # pad the *local row* side so the row-block loop stays in bounds;
        # the ring payload (columns) keeps the uniform shard size m_l.
        pad = (-m_l) % row_block
        mp = m_l + pad
        own_p = tuple(jnp.pad(a, ((0, pad), (0, 0))) for a in own)
        sqp = jnp.pad(jnp.sum(own[0] * own[0], axis=-1), (0, pad))

        def ring_step(t, carry):
            datab, cb, sqb, acc = carry
            Xb = block_dense(*datab)              # dense view of ring block

            def rb(i, acc):
                s = i * row_block
                Xi = block_dense(*(lax.dynamic_slice_in_dim(a, s, row_block)
                                   for a in own_p))
                sqi = lax.dynamic_slice_in_dim(sqp, s, row_block)
                if kernel == "rbf":
                    d2 = sqi[:, None] - 2.0 * (Xi @ Xb.T) + sqb[None, :]
                    Kb = jnp.exp(-jnp.maximum(d2, 0.0) * inv_2s2)
                elif kernel == "linear":
                    Kb = Xi @ Xb.T
                else:
                    Kb = (inv_2s2 * (Xi @ Xb.T) + 1.0) ** 3
                return lax.dynamic_update_slice_in_dim(
                    acc, lax.dynamic_slice_in_dim(acc, s, row_block) + Kb @ cb,
                    s, axis=0)

            acc = lax.fori_loop(0, mp // row_block, rb, acc)
            perm = [(i, (i + 1) % p) for i in range(p)]
            rotate = lambda a: lax.ppermute(a, axis, perm)
            return (tuple(rotate(a) for a in datab), rotate(cb), rotate(sqb),
                    acc)

        _, _, _, acc = lax.fori_loop(
            0, p, ring_step,
            (ring0, coef_l, sq_ring, jnp.zeros((mp,), jnp.float32)))
        return jnp.where(stale_l, acc[:m_l] - y_l, gamma_l)

    sharded = P(axis)
    mapped = shard_map_compat(
        local, mesh=mesh,
        in_specs=(P(axis, None),) * n_data + (sharded,) * 4,
        out_specs=sharded)
    return jax.jit(mapped)


def make_cache_warmer(mesh: Mesh, kernel: str, inv_2s2: float,
                      axis: str = AXIS, use_pallas: bool = False,
                      fmt: str = "dense", n_features: int = 0,
                      pairs: bool = True):
    """shard_map row-cache rewarm across un-shrink growth: replicated
    (S, d) tag queries in, each shard recomputes its own (S, M_local)
    value-table segment over its local buffer view with the exact in-loop
    compute islands (``rowcache.warm_vals``) — so post-growth hits serve
    the bits an in-loop miss on that shard would have produced."""
    provider = kernel_fns.make_provider(kernel, fmt, use_pallas, inv_2s2)
    n_data = 3 if fmt == "ell" else 2

    def local(*args):
        if fmt == "ell":
            vals_l, cols_l, sq_l = args[:3]
            ldata = dataplane.ELLData(vals_l, cols_l, sq_l, n_features)
        else:
            X_l, sq_l = args[:2]
            ldata = dataplane.DenseData(X_l, sq_l)
        zq, tags, never = args[n_data:]
        return rowcache.warm_vals(provider, ldata, zq, tags, never, pairs)

    sharded = P(axis)
    rep = P()
    data_specs = ((P(axis, None), P(axis, None), sharded) if fmt == "ell"
                  else (P(axis, None), sharded))
    mapped = shard_map_compat(local, mesh=mesh,
                              in_specs=data_specs + (rep, rep, rep),
                              out_specs=P(None, axis))
    return jax.jit(mapped)


class ParallelSMOSolver(solver.SMOSolver):
    """Multi-device SMO with adaptive shrinking, trained through the
    *same* :class:`repro.core.driver.EpochDriver` as the single-host
    solver — this class only swaps the hook surface: Alg. 3/4 shard_map
    chunk runners (``_runner``), mesh placement (``_put`` /
    ``_put_cache_vals`` / ``_put_full``), compaction output-sharding pins
    (``_compact_shardings`` — the device compaction step re-gathers buffer
    rows *and* reshards the mesh-sharded cache value table in one jitted
    program), and Alg. 6 ring reconstruction (``_reconstruct``). The
    Single/Multi policy logic, checkpoint/resume, and compaction scheduling
    exist once, in the driver."""

    def __init__(self, config: solver.SVMConfig, mesh: Optional[Mesh] = None,
                 axis: str = AXIS, devices: Optional[int] = None):
        """``devices``: train on the first N visible devices (an elastic
        rescale target — resuming a checkpoint here re-deals buffers and
        mirror shards for THIS mesh regardless of the mesh it was saved
        under). Mutually exclusive with an explicit ``mesh``."""
        super().__init__(config)
        if devices is not None and mesh is not None:
            raise ValueError("pass either mesh or devices, not both")
        if devices is not None:
            mesh = data_mesh(devices, axis=axis)
        self.mesh = mesh if mesh is not None else data_mesh(axis=axis)
        self.axis = axis if mesh is None else self.mesh.axis_names[0]
        self._sharding = NamedSharding(self.mesh, P(self.axis))
        self._sharding2d = NamedSharding(self.mesh, P(self.axis, None))
        self._runners: dict = {}

    def _nshards(self) -> int:
        return int(np.prod(self.mesh.devices.shape))

    def _put(self, arr: np.ndarray):
        sh = self._sharding2d if arr.ndim == 2 else self._sharding
        return jax.device_put(jnp.asarray(arr), sh)

    def _put_full(self, arr: np.ndarray):
        """(n,) alpha/gamma device masters: replicated — they are touched
        only by the compaction scatter and epoch-boundary writeback."""
        return jax.device_put(jnp.asarray(arr),
                              NamedSharding(self.mesh, P()))

    def _put_cache_vals(self, arr: np.ndarray):
        """(slots, M) cache value table sharded on the buffer axis — each
        shard caches its own M_local row segments, zero extra collectives."""
        return jax.device_put(jnp.asarray(arr),
                              NamedSharding(self.mesh, P(None, self.axis)))

    def _compact_shardings(self):
        """Pin the device compaction step's outputs to the mesh layout the
        chunk runner expects (rows/vectors on the buffer axis, cache value
        table transposed-sharded, scalars and masters replicated)."""
        mk = lambda spec: NamedSharding(self.mesh, spec)
        return driver.CompactShardings(
            rows=mk(P(self.axis, None)), vec=mk(P(self.axis)),
            cache_vals=mk(P(None, self.axis)), rep=mk(P()))

    def _runner(self, cfg, interval):
        fmt = self._store.fmt
        # n_features is baked into the ELL closures (candidate-row densify),
        # so it must key the cache: a refit on a different-width dataset
        # would otherwise silently scatter out-of-bounds.
        policy = cfg.row_cache_policy if self._cache_slots() else "lru"
        key = (cfg.kernel, cfg.C, cfg.inv_2s2, interval, cfg.use_pallas, fmt,
               self._store.n_features, cfg.selection, self._cache_slots(),
               policy)
        if key not in self._runners:
            self._runners[key] = make_parallel_chunk_runner(
                self.mesh, cfg.kernel, cfg.C, cfg.inv_2s2, interval,
                self.axis, cfg.use_pallas, fmt=fmt,
                n_features=self._store.n_features, selection=cfg.selection,
                cache_slots=self._cache_slots(), cache_policy=policy)
        return self._runners[key]

    # -- Alg. 6: the ppermute ring, fed from host arrays or the mirror ----
    def _ring(self, fmt: str):
        store = self._store
        rb = min(4096, util.next_pow2(max(64, store.n)))
        # row_block and (for ELL) n_features are closed over by the ring —
        # key them so refits on different datasets rebuild the closure.
        key = ("recon", self.cfg.kernel, self.cfg.inv_2s2, fmt, rb,
               store.n_features)
        if key not in self._runners:
            self._runners[key] = make_ring_reconstructor(
                self.mesh, self.cfg.kernel, self.cfg.inv_2s2, self.axis,
                row_block=rb, fmt=fmt, n_features=store.n_features)
        return self._runners[key]

    def _full_layout(self):
        """The full set's balanced p-shard buffer layout (position -> gid,
        -1 on per-shard padding tails) and its inverse. Host-built ring
        inputs use this layout so they are positioned EXACTLY like the
        device mirror — the two reconstruction modes then run the same
        ring executable on bit-identical arrays."""
        store, p = self._store, self._nshards()
        m_per = mirror_mod.full_m_per(store.n, p, self.cfg.min_buffer)
        return dataplane.full_layout(np.arange(store.n), p, m_per)

    def _sv_lane_budget(self, sv: np.ndarray) -> int:
        return reconstruct.sv_lane_budget(self._store, sv,
                                          self.cfg.ell_adaptive)

    def _reconstruct(self, y, alpha, stale):
        """Distributed Alg. 6, host-streaming backend (``mirror='host'`` /
        fallback): build the ring inputs in host numpy — full-set rows in
        the mirror layout, the SV-masked ring payload at the SV set's lane
        budget — and run the ppermute ring; returns gamma for ``stale``.

        ELL-family stores (``ELLStore``/``CSRStore``) send two sparse
        payloads: own-side rows at the full set's adaptive K, and the ring
        payload restricted to support-vector rows at the *SV set's*
        lane-rounded K — non-SV rows carry coef 0, so zeroing them is exact
        and the rotated bytes track the live model, not the ingest budget.
        Both K's are trace dimensions of the jitted ring, power-of-two
        bucketed (``ell_adaptive=False`` pins them to the store budget)."""
        store = self._store
        n = store.n
        fmt = store.fmt
        recon = self._ring(fmt)
        idx, pos_of = self._full_layout()
        m = idx.size
        real = idx >= 0
        rid = idx[real]
        stale_mask_n = np.zeros((n,), bool)
        stale_mask_n[stale] = True
        yb = np.ones((m,), np.float32)
        yb[real] = y[rid]
        ab = np.zeros((m,), np.float32)
        ab[real] = alpha[rid]
        sb = np.zeros((m,), bool)
        sb[real] = stale_mask_n[rid]
        if fmt == "ell":
            K_own = (spfmt.bucket_lanes(store.buffer_K(np.arange(n)),
                                        store.lane, cap=store.K)
                     if self.cfg.ell_adaptive else store.K)
            buf = store.alloc(m, K_own)
            for sl, sub in dataplane.deal(np.arange(n), self._nshards(),
                                          m // self._nshards()):
                store.fill(buf, sl, sub)
            vp, cp = buf
            sv = np.flatnonzero(alpha > 0.0)
            K_sv = self._sv_lane_budget(sv)
            rvp = np.zeros((m, K_sv), np.float32)
            rcp = np.zeros((m, K_sv), np.int32)
            if sv.size:
                store.fill((rvp, rcp), pos_of[sv], sv)
            dargs = (self._put(vp), self._put(cp),
                     self._put(rvp), self._put(rcp))
        else:
            Xp = np.zeros((m, store.n_features), np.float32)
            Xp[real] = store.X[rid]
            dargs = (self._put(Xp),)
        g = recon(*dargs, self._put(yb), self._put(ab),
                  self._put(np.zeros((m,), np.float32)), self._put(sb))
        return np.asarray(g)[pos_of[stale]]

    def _reconstruct_mirror(self, mir, alpha_d, gamma_d, sv_rows, stale):
        """Distributed Alg. 6 over the device mirror: the same ring
        executable as :meth:`_reconstruct`, but every input is derived on
        device — per-position alpha gathered from the (n,) master, the
        SV-masked ring payload masked out of the mirror rows — and the
        stale outputs are scattered straight into the donated gamma
        master. Host traffic: one (n,) stale mask up, nothing per block."""
        cfg, store = self.cfg, self._store
        fmt = store.fmt
        n = store.n
        recon = self._ring(fmt)
        stale_mask_n = np.zeros((n,), bool)
        stale_mask_n[stale] = True
        stale_full = self._put_full(stale_mask_n)
        gids = mir.data.gids
        if "recon_prep" not in self._runners:
            rep = NamedSharding(self.mesh, P())

            @functools.partial(jax.jit, static_argnames=("K_sv",))
            def prep(data, alpha_d, stale_full, *, K_sv):
                valid = data.gids >= 0
                safe = jnp.where(valid, data.gids, 0)
                ab = jnp.where(valid, alpha_d[safe], 0.0)
                sb = valid & stale_full[safe]
                gamma0 = jnp.zeros_like(ab)
                if K_sv is None:
                    return ab, sb, gamma0, ()
                is_sv = jnp.where(valid, alpha_d[safe] > 0.0, False)
                rvp = jnp.where(is_sv[:, None], data.vals[:, :K_sv], 0.0)
                rcp = jnp.where(is_sv[:, None], data.cols[:, :K_sv], 0)
                return ab, sb, gamma0, (rvp, rcp)

            @functools.partial(jax.jit, donate_argnames=("gamma_d",),
                               static_argnames=("n",), out_shardings=rep)
            def scatter(gamma_d, out, gids, sb, *, n):
                tgt = jnp.where(sb, jnp.where(gids >= 0, gids, n), n)
                return gamma_d.at[tgt].set(out, mode="drop")

            self._runners["recon_prep"] = (prep, scatter)
        prep, scatter = self._runners["recon_prep"]
        K_sv = self._sv_lane_budget(sv_rows) if fmt == "ell" else None
        ab, sb, gamma0, ring_payload = prep(mir.data, alpha_d, stale_full,
                                            K_sv=K_sv)
        dargs = ((mir.data.vals, mir.data.cols) + ring_payload
                 if fmt == "ell" else (mir.data.X,))
        out = recon(*dargs, mir.y, ab, gamma0, sb)
        return scatter(gamma_d, out, gids, sb, n=n)

    def _regrow_cache(self, cache, data, pairs: bool, n: int):
        """Rewarm the mesh-sharded cache value table across un-shrink
        growth: tag query rows are gathered globally (replicated — the
        same S x d bytes the candidate all_gather ships per iteration),
        then each shard recomputes its own (S, M_local) segment under
        shard_map with the exact in-loop compute structure
        (``rowcache.warm_vals`` on the local buffer view)."""
        cfg = self.cfg
        fmt = self._store.fmt
        key = ("warm", cfg.kernel, cfg.inv_2s2, fmt, self._store.n_features,
               cfg.use_pallas, pairs)
        if key not in self._runners:
            self._runners[key] = make_cache_warmer(
                self.mesh, cfg.kernel, cfg.inv_2s2, self.axis,
                cfg.use_pallas, fmt=fmt, n_features=self._store.n_features,
                pairs=pairs)
        if "tag_queries" not in self._runners:
            self._runners["tag_queries"] = jax.jit(
                rowcache.tag_queries, static_argnames=("n",),
                out_shardings=NamedSharding(self.mesh, P()))
        zq = self._runners["tag_queries"](data, cache.tags, n=n)
        dargs = ((data.vals, data.cols, data.sq_norms) if fmt == "ell"
                 else (data.X, data.sq_norms))
        vals = self._runners[key](*dargs, zq, cache.tags, jnp.asarray(False))
        return cache._replace(vals=vals)
