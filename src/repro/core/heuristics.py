"""Shrinking heuristics — the paper's Table 3.

Two independent dials (Sec. 3.3.1):

* when to shrink      — ``random: k``       fixed iteration interval k
                        ``numsamples: f``   interval = f * |X| iterations
* reconstruction      — ``single``  one gamma-reconstruction; optimization
                                    continues WITHOUT shrinking afterwards
                        ``multi``   reconstruct whenever the active set
                                    converges; shrinking continues throughout

Class tags from the paper: * aggressive, <> average, . conservative.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal

Policy = Literal["none", "single", "multi"]


@dataclasses.dataclass(frozen=True)
class ShrinkHeuristic:
    name: str
    policy: Policy
    kind: Literal["random", "numsamples", "none"] = "none"
    value: float = 0.0
    klass: str = "N/A"  # aggressive / average / conservative

    def interval(self, n_total: int) -> int:
        """Iterations between shrink-rule applications (0 = never)."""
        if self.policy == "none":
            return 0
        if self.kind == "random":
            return max(1, int(self.value))
        return max(1, int(math.ceil(self.value * n_total)))


def fuse_budget(fuse_iters: int, ckpt_count: int, checkpoint_every: int) -> int:
    """Segments the next fused dispatch may run without crossing a
    checkpoint boundary.

    The checkpoint cadence is counted in *segments* (one segment == one
    legacy dispatch), so a k-segment epoch must stop exactly where the
    k=1 oracle would have saved: min(k, segments until the next multiple
    of ``checkpoint_every``). Always >= 1; ``checkpoint_every <= 0`` (or
    no checkpointing) leaves k uncapped.
    """
    k = max(1, int(fuse_iters))
    if checkpoint_every <= 0:
        return k
    return min(k, checkpoint_every - ckpt_count % checkpoint_every)


ORIGINAL = ShrinkHeuristic("Original", "none")

# Rows 2-13 of Table 3.
TABLE3: dict[str, ShrinkHeuristic] = {"original": ORIGINAL}
for _policy in ("single", "multi"):
    _P = _policy.capitalize()
    for _k, _cls in ((2, "aggressive"), (500, "aggressive"), (1000, "average")):
        _h = ShrinkHeuristic(f"{_P}{_k}", _policy, "random", _k, _cls)
        TABLE3[_h.name.lower()] = _h
    for _pc, _cls in ((5, "aggressive"), (10, "average"), (50, "conservative")):
        _h = ShrinkHeuristic(f"{_P}{_pc}pc", _policy, "numsamples", _pc / 100.0, _cls)
        TABLE3[_h.name.lower()] = _h


def get(name_or_h: "str | ShrinkHeuristic") -> ShrinkHeuristic:
    if isinstance(name_or_h, ShrinkHeuristic):
        return name_or_h
    try:
        return TABLE3[name_or_h.lower()]
    except KeyError:
        raise ValueError(
            f"unknown heuristic {name_or_h!r}; known: {sorted(TABLE3)}") from None
