"""Batched multi-problem SMO training: K binary subproblems over ONE
resident buffer, with amortized kernel-row production.

The paper's Table 3 grid and every production deployment train *many*
binary problems — one-vs-rest classes and (C, gamma) hyperparameter
sweeps — yet kernel rows K(i, .) depend only on X, never on a problem's
alpha. This module turns K sequential fits into one batched device
program over a single data buffer:

  * per-problem state is stacked on a leading problem axis — alpha/gamma/
    active are (K, M), selection scalars are (K,) (:class:`MultiSMOState`);
  * pair selection, the analytic update, and the shrink rule run as the
    vectorized ``smo.*_multi`` twins of the scalar machinery — elementwise
    f32 plus exact-comparison argmin/argmax, so each problem's trajectory
    is bit-identical to running it alone given the same row bits;
  * kernel-row production is amortized across problems. Cache OFF: the 2K
    working-set rows of one joint iteration are produced by ONE stacked
    (M, 2K) provider GEMM — X is read once per iteration instead of K
    times, which is where the aggregate us/iter*problem win comes from
    (row production is memory-bound). Cache ON: each problem's pair goes
    through ONE shared ``rowcache.RowCache`` keyed by global row id, so a
    row any problem produced serves every other problem's hit — the
    cached path reuses the exact per-problem compute islands of the
    scalar runner and is bit-identical to the ``multi_backend='loop'``
    oracle per problem;
  * per-problem convergence masks retire finished problems *inside* the
    ``lax.while_loop``: a frozen problem's lanes are carried through
    ``jnp.where`` (never ``lax.cond`` — a cond would copy the (S, M)
    cache table every iteration) and its idle cache accesses are counter-
    gated (``live=`` on the accessors), so K problems of different
    difficulty share one dispatch without distorting the statistics;
  * shrinking stays per-problem *logical* (the (K, M) active masks);
    *physical* compaction triggers on the union of live problems'
    survivors — a row leaves the buffer only when every problem shrank it
    (the intersection of shrunk sets), so the paper's adaptive-shrinking
    FLOP win survives without K divergent buffer geometries.

:class:`MultiProblemDriver` owns the host control flow (the Alg. 5 state
machine per problem lane): per-problem tolerance schedules, gradient
reconstruction + un-shrink + retirement, union compaction, checkpoint
save/resume of the (K, n) masters, and per-problem finalize into ordinary
:class:`repro.core.solver.SVMModel`s. ``multi_backend='loop'`` runs the K
problems through the ordinary single-problem solver sequentially — the
parity oracle and the baseline ``BENCH_multi.json`` beats.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import math
import os
import time
import warnings
from typing import NamedTuple, Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from repro.core import dataplane, driver, kernel_fns, mirror, rowcache, smo, util
from repro.core import heuristics as H
from repro.core.solver import SVMConfig, SVMModel, SMOSolver
from repro.data import sparse as spfmt

_INT32_MAX = 2**31 - 1   # per-problem shrink-disable sentinel for next_shrink


class MultiSMOState(NamedTuple):
    """K stacked problem states over one shared buffer of M rows."""
    alpha: jax.Array        # (K, M) f32
    gamma: jax.Array        # (K, M) f32
    active: jax.Array       # (K, M) bool — per-problem logical shrink mask
    beta_up: jax.Array      # (K,) f32
    beta_low: jax.Array     # (K,) f32
    i_up: jax.Array         # (K,) i32
    i_low: jax.Array        # (K,) i32
    step: jax.Array         # (K,) i32 — per-problem iteration counters
    next_shrink: jax.Array  # (K,) i32 (INT32_MAX = shrinking disabled)
    n_shrinks: jax.Array    # (K,) i32
    converged: jax.Array    # (K,) bool
    stalled: jax.Array      # (K,) bool
    live: jax.Array         # (K,) bool — False once the driver retires the
                            # problem; frozen lanes stop advancing but stay
                            # resident (jnp.where carries, no cond)


class MultiEpochSummary(NamedTuple):
    """Fixed-size readback of one batched dispatch — (K,) lanes where the
    single-problem :class:`repro.core.smo.EpochSummary` had scalars."""
    step: jax.Array          # (K,) i32 per-problem iteration counters
    segs: jax.Array          # i32 segments actually run
    joint_iters: jax.Array   # i32 joint while-loop body executions (the
                             # stacked row GEMM runs once per joint iter —
                             # what production FLOPs are billed on)
    n_active: jax.Array      # (K,) i32 per-problem active counts
    n_active_union: jax.Array  # i32 rows active for >= 1 live problem
    n_shrinks: jax.Array     # (K,) i32
    converged: jax.Array     # (K,) bool
    stalled: jax.Array       # (K,) bool
    need_compact: jax.Array  # bool — union compaction predicate
    cache_hits: jax.Array    # i32 (live-gated; 0 when cache off)
    cache_misses: jax.Array  # i32


def init_multi_state(alpha, gamma, active, step, next_shrink, n_shrinks,
                     live) -> MultiSMOState:
    """Fresh batched state around (K, M) buffer arrays; selection lanes are
    (re)established by the runner before its first iteration."""
    Kp = alpha.shape[0]
    return MultiSMOState(
        alpha=alpha, gamma=gamma, active=active,
        beta_up=jnp.full((Kp,), -1.0, jnp.float32),
        beta_low=jnp.full((Kp,), 1.0, jnp.float32),
        i_up=jnp.zeros((Kp,), jnp.int32),
        i_low=jnp.zeros((Kp,), jnp.int32),
        step=jnp.asarray(step, jnp.int32),
        next_shrink=jnp.asarray(next_shrink, jnp.int32),
        n_shrinks=jnp.asarray(n_shrinks, jnp.int32),
        converged=jnp.zeros((Kp,), bool),
        stalled=jnp.zeros((Kp,), bool),
        live=jnp.asarray(live, bool),
    )


def make_multi_runner(kernel: str, inv_2s2: float, shrink_interval: int,
                      use_pallas: bool = False, shrink_min_interval: int = 1,
                      selection: str = "wss1", fmt: str = "dense",
                      cache_slots: int = 0, cache_policy: str = "lru"):
    """Build the jitted batched fused-epoch runner::

        state, cache, summary = run_epoch(data, ystk, state, cache,
                                          thr0, thr1, Cv, tol,
                                          k, chunk_iters, max_iters,
                                          compact_lt, mper_lo)

    The problem axis K is a trace dimension (``ystk.shape[0]``), so one
    runner serves every K; per-problem box constants (``thr0``/``thr1``/
    ``Cv`` from :func:`smo.box_thresholds`) and tolerances ``tol`` ride as
    traced (K,) arrays, so a (C, gamma=shared) grid shares one executable.

    Row production per joint iteration:

      * cache off — one stacked (M, 2K) provider ``rows2`` GEMM over the
        interleaved [up_0, low_0, up_1, low_1, ...] working-set rows,
        wrapped in the exact barrier/degenerate-cond island structure of
        ``rowcache.make_accessors`` (at K=1 this IS the scalar runner's
        compute, bit for bit);
      * cache on — K per-problem accesses through the ONE shared cache
        (python-unrolled: K is static at trace time), each the exact
        scalar compute island, so cached batched trajectories are
        bit-identical per problem to the sequential-loop oracle while
        cross-problem reuse multiplies the hit rate.

    Frozen problems (converged/stalled/retired lanes) keep issuing their
    last row request — the access pattern must stay trace-static — but
    their state is carried through ``jnp.where`` and their cache counters
    are gated with ``live=run_k``.

    ``use_pallas`` routes row production through the per-problem unrolled
    islands even with the cache off (the Pallas rows2 kernels are
    validated at B=2); the stacked GEMM is a jnp-provider path.
    """
    row1 = kernel_fns.get_row(kernel)
    kself = kernel_fns.self_kernel(kernel)
    provider = kernel_fns.make_provider(kernel, fmt, use_pallas, inv_2s2)
    cached = cache_slots > 0
    stacked = (not cached) and (not use_pallas)

    @functools.partial(jax.jit, donate_argnums=(2, 3))
    def run_epoch(data, ystk, state: MultiSMOState, cache, thr0, thr1, Cv,
                  tol, k, chunk_iters, max_iters, compact_lt, mper_lo):
        Kp = ystk.shape[0]
        kk = jnp.arange(Kp)
        m = data.m

        if selection == "wss2":
            kdiag = provider.diag(data)

        never = tol[0] < 0.0                    # traced False (runtime)
        get_row1, get_rows2 = rowcache.make_accessors(
            provider, data, cached, never, cache_policy)

        def rows_dense(idx):                    # (K,) -> (K, d)
            return jnp.stack([data.dense_row(idx[i]) for i in range(Kp)])

        def kself_v(z):                         # (K, d) -> (K,)
            return jnp.stack([jnp.asarray(kself(z[i], inv_2s2), jnp.float32)
                              for i in range(Kp)])

        def stacked_rows(zq, ncols):
            # The uncached stacked production island — same cond/barrier
            # structure as the accessors' uncached path (load-bearing for
            # the K=1 == scalar-runner bitwise contract).
            zero = jnp.zeros(data.sq_norms.shape + (ncols,), jnp.float32)
            compute = lambda: lax.optimization_barrier(
                provider.rows2(data, lax.optimization_barrier(zq)))
            return lax.cond(never, lambda: zero, compute)

        def run_segment(s: MultiSMOState, c, jiters):
            b_up, i_up, b_low, i_low = smo.select_pair_multi(
                s.gamma, s.alpha, ystk, s.active, thr0, thr1)
            s = s._replace(beta_up=b_up, i_up=i_up, beta_low=b_low,
                           i_low=i_low, converged=b_up + tol >= b_low,
                           stalled=jnp.zeros((Kp,), bool))
            start = s.step
            lim = jnp.minimum(chunk_iters,
                              jnp.maximum(1, max_iters - start))    # (K,)

            def running(s):
                return (s.live & (~s.converged) & (~s.stalled)
                        & (s.step - start < lim))

            def cond(carry):
                s, _, _ = carry
                return jnp.any(running(s))

            def body(carry):
                s, c, j = carry
                run = running(s)                                   # (K,)
                iu = s.i_up
                x_up = rows_dense(iu)                              # (K, d)
                y_up = ystk[kk, iu]
                a_up = s.alpha[kk, iu]
                k_uu = kself_v(x_up)

                if selection == "wss2":
                    if cached:
                        ru = []
                        for i in range(Kp):
                            r, c = get_row1(c, data.gids[iu[i]], x_up[i],
                                            live=run[i])
                            ru.append(r)
                        row_up = jnp.stack(ru)                     # (K, M)
                    elif stacked:
                        # duplicated queries (row_via_rows2's context-
                        # stability trick, batched): (2K, d) -> take even
                        # columns, so per-problem bits match the scalar
                        # single-row island
                        rows = stacked_rows(jnp.repeat(x_up, 2, axis=0),
                                            2 * Kp)
                        row_up = rows[:, ::2].T
                    else:
                        ru = []
                        for i in range(Kp):
                            r, c = get_row1(c, None, x_up[i])
                            ru.append(r)
                        row_up = jnp.stack(ru)
                    scores = smo.wss2_scores_multi(
                        s.gamma, s.alpha, ystk, s.active, thr0, thr1,
                        s.beta_up, row_up, kdiag, k_uu)
                    il = jnp.argmax(scores, axis=1).astype(jnp.int32)
                    g_low = s.gamma[kk, il]
                else:
                    il = s.i_low
                    g_low = s.beta_low
                x_low = rows_dense(il)
                y_low = ystk[kk, il]
                a_low = s.alpha[kk, il]

                if selection == "wss2":
                    k_ul = row_up[kk, il]
                else:
                    # per-problem O(d) scalar islands — identical to the
                    # scalar runner's barriered row1 subgraph per problem
                    kul = []
                    for i in range(Kp):
                        xu_b, xl_b = lax.optimization_barrier(
                            (x_up[i], x_low[i]))
                        kul.append(lax.optimization_barrier(
                            row1(xl_b[None, :], jnp.sum(xl_b * xl_b)[None],
                                 xu_b, inv_2s2)[0]))
                    k_ul = jnp.stack(kul)
                k_ll = kself_v(x_low)

                # Per-problem unrolled update math, NOT one (K,) vectorized
                # pair_update: at K >= 4 the vectorized (K,) f32 chain is
                # contracted into FMAs differently than the scalar runner's
                # codegen (observed: 1-ulp alpha drift vs the loop oracle
                # at K=4, none at K <= 3). Unrolled scalar lanes keep the
                # scalar runner's contraction.
                ups, lows = [], []
                for i in range(Kp):
                    u, l = smo.pair_update_multi(
                        a_up[i], a_low[i], y_up[i], y_low[i],
                        s.beta_up[i], g_low[i], k_ul[i], k_uu[i],
                        k_ll[i], Cv[i])
                    ups.append(u)
                    lows.append(l)
                a_up_new = jnp.stack(ups)
                a_low_new = jnp.stack(lows)
                d_up = a_up_new - a_up
                d_low = a_low_new - a_low
                stall_new = ((jnp.abs(d_up) < smo._TAU)
                             & (jnp.abs(d_low) < smo._TAU))
                stalled = jnp.where(run, stall_new, s.stalled)

                alpha_new = s.alpha.at[kk, iu].set(a_up_new) \
                                   .at[kk, il].set(a_low_new)
                alpha = jnp.where(run[:, None], alpha_new, s.alpha)

                coef2 = jnp.stack([y_up * d_up, y_low * d_low], axis=1)
                if selection == "wss2":
                    if cached:
                        rl = []
                        for i in range(Kp):
                            r, c = get_row1(c, data.gids[il[i]], x_low[i],
                                            live=run[i])
                            rl.append(r)
                        row_low = jnp.stack(rl)
                    elif stacked:
                        rows = stacked_rows(jnp.repeat(x_low, 2, axis=0),
                                            2 * Kp)
                        row_low = rows[:, ::2].T
                    else:
                        rl = []
                        for i in range(Kp):
                            r, c = get_row1(c, None, x_low[i])
                            rl.append(r)
                        row_low = jnp.stack(rl)
                    gamma_new = (s.gamma + coef2[:, :1] * row_up
                                 + coef2[:, 1:] * row_low)
                elif cached:
                    gn = []
                    for i in range(Kp):
                        gid2 = jnp.stack([data.gids[iu[i]],
                                          data.gids[il[i]]])
                        z2 = jnp.stack([x_up[i], x_low[i]])
                        rows_i, c = get_rows2(c, gid2, z2, live=run[i])
                        gn.append(provider.gamma_from_rows(
                            s.gamma[i], rows_i, coef2[i]))
                    gamma_new = jnp.stack(gn)
                elif stacked:
                    # ONE (M, 2K) GEMM for all K pairs — X read once per
                    # joint iteration. Interleaved [up_0, low_0, ...] so
                    # problem i consumes columns (2i, 2i+1) exactly like
                    # its scalar (M, 2).
                    z_all = jnp.stack([x_up, x_low], axis=1) \
                               .reshape(2 * Kp, -1)
                    rows = stacked_rows(z_all, 2 * Kp)
                    gamma_new = jnp.stack([
                        provider.gamma_from_rows(
                            s.gamma[i], rows[:, 2 * i: 2 * i + 2], coef2[i])
                        for i in range(Kp)])
                else:
                    gn = []
                    for i in range(Kp):
                        z2 = jnp.stack([x_up[i], x_low[i]])
                        rows_i, c = get_rows2(c, None, z2)
                        gn.append(provider.gamma_from_rows(
                            s.gamma[i], rows_i, coef2[i]))
                    gamma_new = jnp.stack(gn)
                gamma = jnp.where(run[:, None], gamma_new, s.gamma)

                step1 = s.step + run.astype(jnp.int32)
                if shrink_interval > 0:
                    do_shrink = run & (step1 >= s.next_shrink)
                    # the shrink rule lives in a cond branch exactly like
                    # the scalar runner's: computed in the main region it
                    # becomes a fusion consumer of the gamma producer, and
                    # XLA duplicates the FMA epilogue into the consumer
                    # with context-dependent contraction (observed: 1-ulp
                    # gamma drift vs the loop oracle the moment shrinking
                    # was enabled). Branch regions codegen separately, so
                    # the cond leaves the producer fusion untouched.
                    active = lax.cond(
                        jnp.any(do_shrink),
                        lambda: jnp.where(
                            do_shrink[:, None],
                            smo.shrink_rule_multi(
                                gamma, alpha, ystk, s.active,
                                s.beta_up, s.beta_low, thr0, thr1),
                            s.active),
                        lambda: s.active)
                else:
                    do_shrink = jnp.zeros((Kp,), bool)
                    active = s.active
                n_active = jnp.sum(active, axis=1)
                interval = jnp.maximum(
                    jnp.minimum(jnp.int32(shrink_interval), n_active),
                    shrink_min_interval)
                next_shrink = jnp.where(do_shrink, step1 + interval,
                                        s.next_shrink)
                n_shrinks = s.n_shrinks + do_shrink.astype(jnp.int32)

                b_up, i_up2, b_low, i_low2 = smo.select_pair_multi(
                    gamma, alpha, ystk, active, thr0, thr1)
                converged = jnp.where(run, b_up + tol >= b_low, s.converged)
                s2 = MultiSMOState(
                    alpha=alpha, gamma=gamma, active=active,
                    beta_up=jnp.where(run, b_up, s.beta_up),
                    beta_low=jnp.where(run, b_low, s.beta_low),
                    i_up=jnp.where(run, i_up2, s.i_up),
                    i_low=jnp.where(run, i_low2, s.i_low),
                    step=step1, next_shrink=next_shrink,
                    n_shrinks=n_shrinks, converged=converged,
                    stalled=stalled, live=s.live)
                return (s2, c, j + 1)

            return lax.while_loop(cond, body, (s, c, jiters))

        def epoch_cond(carry):
            _, _, segs, _, done, _ = carry
            return (~done) & (segs < k)

        def epoch_body(carry):
            s, c, segs, jiters, _, _ = carry
            s, c, jiters = run_segment(s, c, jiters)
            runmask = (s.live & (~s.converged) & (~s.stalled)
                       & (s.step < max_iters))
            hard = ~jnp.any(runmask)
            act_live = s.active & s.live[:, None]
            n_act = jnp.sum(jnp.any(act_live, axis=0)).astype(jnp.int32)
            if shrink_interval > 0:
                # union twin of the scalar compaction predicate: compact
                # only when the rows active for >= 1 live problem (i.e.
                # keep = union of survivors; drop = intersection of shrunk
                # sets) fit a genuinely smaller pow2 bucket
                m_per_new = util.bucket_pow2_device(n_act, mper_lo)
                need_c = (~hard) & (n_act < compact_lt) & (m_per_new < m)
            else:
                need_c = jnp.bool_(False)
            return (s, c, segs + 1, jiters, hard | need_c, need_c)

        carry0 = (state, cache, jnp.int32(0), jnp.int32(0),
                  jnp.bool_(False), jnp.bool_(False))
        s, c, segs, jiters, _, need_c = lax.while_loop(
            epoch_cond, epoch_body, carry0)

        act_live = s.active & s.live[:, None]
        summ = MultiEpochSummary(
            step=s.step, segs=segs, joint_iters=jiters,
            n_active=jnp.sum(s.active, axis=1).astype(jnp.int32),
            n_active_union=jnp.sum(jnp.any(act_live, axis=0))
            .astype(jnp.int32),
            n_shrinks=s.n_shrinks, converged=s.converged, stalled=s.stalled,
            need_compact=need_c,
            cache_hits=c.hits if cached else jnp.int32(0),
            cache_misses=c.misses if cached else jnp.int32(0))
        return s, c, summ

    return run_epoch


_MULTI_RUNNER_CACHE: dict = {}


def _multi_runner(cfg: SVMConfig, interval: int, cache_slots: int):
    policy = cfg.row_cache_policy if cache_slots else "lru"
    key = (cfg.kernel, cfg.inv_2s2, interval, cfg.use_pallas, cfg.selection,
           cfg.format, cache_slots, policy)
    if key not in _MULTI_RUNNER_CACHE:
        _MULTI_RUNNER_CACHE[key] = make_multi_runner(
            cfg.kernel, cfg.inv_2s2, interval, cfg.use_pallas,
            selection=cfg.selection, fmt=cfg.format, cache_slots=cache_slots,
            cache_policy=policy)
    return _MULTI_RUNNER_CACHE[key]


def ovr_tasks(y):
    """One-vs-rest task construction: multi-class labels -> (classes,
    (K, n) +-1 label matrix), one binary problem per class in sorted class
    order."""
    y = np.asarray(y)
    classes = np.unique(y)
    Y = np.where(y[None, :] == classes[:, None], 1.0, -1.0).astype(np.float32)
    return classes, Y


@dataclasses.dataclass
class OvRSVMModel:
    """One-vs-rest multi-class model: K binary models + argmax voting.

    ``predict`` routes through ONE union serving engine (``_union``): the
    union support-vector set with a (n_sv, K) coefficient table (0 where a
    row is not an SV of problem k), so a query batch costs one fused
    dispatch per bucket instead of K (see ``core.serve.ServeEngine``'s
    multi-coef path). ``decision_matrix_host`` is the per-model oracle.
    """
    classes: np.ndarray
    models: list
    stats: driver.FitStats
    _union: "SVMModel | None" = None     # union-SV model with (n_sv, K)
                                         # coef table and (K,) beta

    def union_engine(self, **kw):
        if self._union is None:
            raise ValueError("model was built without a union SV set")
        return self._union.serve_engine(**kw)

    def decision_matrix(self, Z) -> np.ndarray:
        """(B, K) decision scores, one column per class."""
        if self._union is not None:
            return np.asarray(self.union_engine().decision_function(Z))
        return self.decision_matrix_host(Z)

    def decision_matrix_host(self, Z) -> np.ndarray:
        """Per-model scoring oracle (K engine dispatch chains)."""
        return np.stack([m.decision_function(Z) for m in self.models],
                        axis=1)

    def predict(self, Z) -> np.ndarray:
        return self.classes[np.argmax(self.decision_matrix(Z), axis=1)]


class MultiProblemDriver:
    """K binary SMO problems over one resident store/buffer.

    ``backend='batched'`` runs the fused multi-problem device program
    (:func:`make_multi_runner`); ``backend='loop'`` trains the K problems
    sequentially through :class:`SMOSolver` — the parity oracle and the
    baseline the batched program is benchmarked against.

    Per-problem control flow mirrors ``driver.EpochDriver.fit`` lane-wise:
    each problem runs at tolerance 20*eps while shrinking, reconstructs
    its gamma over the full set on convergence (Alg. 6 via the host
    streaming oracle), un-shrinks and re-optimizes at 2*eps (Single:
    shrinking disabled via the per-lane ``next_shrink = INT32_MAX``
    sentinel; Multi: counter re-armed), and retires once Eq. 9 holds over
    all samples — retirement freezes its lanes inside subsequent
    dispatches. Physical compaction keeps the union of live problems'
    active rows.

    Constraints of the batched backend: all problems share one kernel
    (``sigma2``/``format``/selection are config-wide; use :func:`fit_grid`
    to partition a (C, sigma2) grid into per-sigma2 batches), and C may
    vary per problem (traced (K,) box constants).
    """

    def __init__(self, config: SVMConfig, backend: str = "batched",
                 parallel: bool = False, mesh=None):
        if backend not in ("batched", "loop"):
            raise ValueError(f"unknown multi backend {backend!r} "
                             "(want 'batched' or 'loop')")
        self.cfg = config
        self.backend = backend
        self.h = H.get(config.heuristic)
        self.parallel = bool(parallel)
        self.mesh = mesh
        self._saves = 0          # checkpoint-save boundary counter (keys
                                 # the launch.chaos on_save hook)
        if self.parallel:
            if backend != "batched":
                raise ValueError("parallel=True requires backend='batched'")
            if config.selection != "wss1":
                raise NotImplementedError(
                    "parallel batched training is wss1-only")
            if config.row_cache or config.use_pallas:
                raise NotImplementedError(
                    "parallel batched training runs cache-off, jnp "
                    "providers only")
            if mesh is None:
                from repro.core import parallel as par
                self.mesh = par.data_mesh()

    # -- public entry points ----------------------------------------------
    def fit_tasks(self, X, Y: np.ndarray, C=None) -> list:
        """Train K binary problems with shared data: ``Y`` is (K, n) in
        {-1, +1}; ``C`` a scalar or (K,) per-problem box. Returns K
        :class:`SVMModel`s (aggregate stats attached to each)."""
        Y = np.ascontiguousarray(Y, np.float32)
        assert Y.ndim == 2, "Y must be (K, n)"
        Kp = Y.shape[0]
        Cs = np.full((Kp,), self.cfg.C, np.float64) if C is None \
            else np.broadcast_to(np.asarray(C, np.float64), (Kp,)).copy()
        if self.backend == "loop":
            return self._fit_loop(X, Y, Cs)
        return self._fit_batched(X, Y, Cs)

    def fit_ovr(self, X, y: np.ndarray) -> OvRSVMModel:
        """One-vs-rest multi-class fit: K = n_classes binary problems over
        one resident store, argmax voting at predict time through the
        union serving engine."""
        classes, Y = ovr_tasks(y)
        models = self.fit_tasks(X, Y)
        union = _union_model(models)
        return OvRSVMModel(classes, models, models[0].stats, union)

    def fit_grid(self, X, y: np.ndarray, Cs, sigma2s=None) -> list:
        """Hyperparameter sweep: one binary problem per grid point. All
        points sharing a sigma2 run as ONE batched fit (C rides as a
        traced per-problem array); distinct sigma2 groups run separate
        batches (the kernel closure is per-runner). Returns models in grid
        order."""
        y = np.ascontiguousarray(y, np.float32)
        Cs = np.asarray(Cs, np.float64).reshape(-1)
        if sigma2s is None:
            Y = np.broadcast_to(y, (Cs.size, y.size))
            return self.fit_tasks(X, Y, C=Cs)
        sigma2s = np.asarray(sigma2s, np.float64).reshape(-1)
        assert sigma2s.size == Cs.size, "Cs and sigma2s must align"
        out: list = [None] * Cs.size
        for s2 in np.unique(sigma2s):
            sel = np.flatnonzero(sigma2s == s2)
            drv = MultiProblemDriver(
                dataclasses.replace(self.cfg, sigma2=float(s2)),
                backend=self.backend, parallel=self.parallel,
                mesh=self.mesh)
            Y = np.broadcast_to(y, (sel.size, y.size))
            ms = drv.fit_tasks(X, Y, C=Cs[sel])
            for j, k in enumerate(sel):
                out[k] = ms[j]
        return out

    # -- loop oracle -------------------------------------------------------
    def _fit_loop(self, X, Y, Cs) -> list:
        models = []
        for k in range(Y.shape[0]):
            ck = dataclasses.replace(self.cfg, C=float(Cs[k]))
            if ck.checkpoint_dir:
                ck = dataclasses.replace(
                    ck, checkpoint_dir=os.path.join(ck.checkpoint_dir,
                                                    f"p{k}"))
            models.append(SMOSolver(ck).fit(X, Y[k]))
        _aggregate_loop_stats(models)
        return models

    # -- batched backend ---------------------------------------------------
    def _fit_batched(self, X, Y, Cs) -> list:
        cfg, h = self.cfg, self.h
        t0 = time.perf_counter()
        if not spfmt.is_csr_like(X):
            X = np.ascontiguousarray(X, np.float32)
        store = self.store = dataplane.make_store(X, cfg.format, cfg.ell_K,
                                                  cfg.ell_lane)
        del X
        n = store.n
        Kp = Y.shape[0]
        assert Y.shape[1] == n
        for k in range(Kp):
            assert set(np.unique(Y[k])) <= {-1.0, 1.0}, "labels must be +-1"
        self.Y = Y
        self.Cs = Cs
        thr0, thr1, Cv = smo.box_thresholds(Cs)
        self._thr = (jnp.asarray(thr0), jnp.asarray(thr1), jnp.asarray(Cv))

        stats = self.stats = driver.FitStats(min_active=n)
        stats.n_problems = Kp
        interval = self._interval = h.interval(n)
        shrink_on = h.policy != "none"
        tol20 = np.float32(cfg.recon_eps_factor * cfg.eps)
        tol2 = np.float32(2.0 * cfg.eps)

        # (K, n) host masters + per-problem control flags
        self.alpha_m = np.zeros((Kp, n), np.float32)
        self.gamma_m = (-Y).astype(np.float32)
        self.act_m = np.ones((Kp, n), bool)
        live = np.ones((Kp,), bool)
        conv_h = np.zeros((Kp,), bool)
        stall_h = np.zeros((Kp,), bool)
        recon_count = np.zeros((Kp,), np.int64)
        shrink_act = np.full((Kp,), shrink_on)
        steps = np.zeros((Kp,), np.int64)
        nshr = np.zeros((Kp,), np.int64)
        retired = [None] * Kp            # per-problem retirement records

        if cfg.resume and cfg.checkpoint_dir:
            got = self._load_ckpt(n, Kp)
            if got is not None:
                (self.alpha_m, self.gamma_m, self.act_m, live, conv_h,
                 stall_h, recon_count, shrink_act, steps, nshr) = got
                stats.reconstructions = int(recon_count.sum())

        cache_slots = (rowcache.bucket_slots(cfg.row_cache_slots)
                       if cfg.row_cache else 0)
        if self.parallel:
            from repro.core import parallel as par
            runner = par.make_parallel_multi_runner(
                self.mesh, cfg.kernel, cfg.inv_2s2,
                interval if shrink_on else 0, fmt=cfg.format,
                n_features=store.n_features)
        else:
            runner = _multi_runner(cfg, interval if shrink_on else 0,
                                   cache_slots)

        next_shrink = np.where(shrink_act, steps + interval, _INT32_MAX)
        if shrink_on and live.any() and self.act_m[live].all():
            rows = np.arange(n)
        elif shrink_on and live.any():
            rows = np.flatnonzero(self.act_m[live].any(axis=0))
        else:
            rows = np.arange(n)
        self._build(rows, steps, next_shrink, nshr, live, conv_h, stall_h)
        self.cache = (rowcache.init_cache(cache_slots, self.data.m)
                      if cache_slots else None)
        self._note_buffer()

        miss_seen = 0
        fuse = max(1, int(cfg.fuse_iters))
        mper_lo = max(cfg.min_buffer, 8)
        t_train = 0.0
        t_recon = 0.0

        while live.any():
            tol_vec = np.where(shrink_act & (recon_count == 0), tol20,
                               tol2).astype(np.float32)
            # parallel mode keeps the buffer full-resident (shrinking is
            # logical only — see make_parallel_multi_runner)
            compact_lt = (math.ceil(cfg.compact_ratio * self.data.m)
                          if shrink_on and not self.parallel else 0)
            tc = time.perf_counter()
            steps_before = steps.copy()
            self.state, self.cache, summ_d = runner(
                self.data, self.ystk, self.state, self.cache,
                *self._thr, jnp.asarray(tol_vec),
                jnp.int32(fuse), jnp.int32(cfg.chunk_iters),
                jnp.int32(cfg.max_iters), jnp.int32(compact_lt),
                jnp.int32(mper_lo))
            summ = jax.device_get(summ_d)
            dt = time.perf_counter() - tc
            t_train += dt
            stats.dispatches += 1
            stats.dispatch_times.append(dt)

            steps = np.asarray(summ.step, np.int64)
            conv = np.asarray(summ.converged)
            stall = np.asarray(summ.stalled)
            nshr = np.asarray(summ.n_shrinks, np.int64)
            iters_done = int((steps - steps_before).sum())
            joint = int(summ.joint_iters)   # per-dispatch (carry resets)
            stats.joint_iters += joint
            stats.min_active = min(stats.min_active,
                                   int(summ.n_active_union))
            # FLOP accounting, multi-problem form (see FitStats): row
            # production is billed ONCE per physically produced row — the
            # miss counter with the cache (cross-problem hits produce
            # nothing), 2K rows per joint iteration for the stacked GEMM —
            # while the O(M) FMA epilogue is billed per problem-iteration
            # (a shared row still feeds K distinct gamma updates).
            if self.cache is not None:
                misses_now = int(summ.cache_misses)
                rows_new = misses_now - miss_seen
                miss_seen = misses_now
            else:
                rows_new = 2 * Kp * joint
            epilogue = 12.0 if cfg.selection == "wss2" else 4.0
            prod = rows_new * self.data.flops_row_pass() * float(self.data.m)
            epi = iters_done * epilogue * float(self.data.m)
            stats.flops_production += prod
            stats.flops_epilogue += epi
            stats.flops_est += prod + epi

            budget = steps >= cfg.max_iters
            done = live & (conv | stall | budget)
            # ONE device->master sync per dispatch, BEFORE any host
            # reconstruction. A later writeback would clobber reconstructed
            # gamma: logically-shrunk rows are still physically in the
            # buffer here (unlike the scalar driver, where compaction
            # removed them), and the device holds their rotten pre-shrink
            # gamma — copying that back over Alg. 6 output makes an
            # un-shrunk lane re-converge instantly and spin to
            # max_reconstructions.
            if (done.any() or bool(summ.need_compact)
                    or bool(cfg.checkpoint_dir)):
                self._writeback()
            unshrink = []
            if done.any():
                for k in np.flatnonzero(done):
                    k = int(k)
                    if ((not shrink_act[k]) or not shrink_on
                            or recon_count[k] >= cfg.max_reconstructions
                            or budget[k]):
                        live[k] = False
                        conv_h[k], stall_h[k] = bool(conv[k]), bool(stall[k])
                        retired[k] = self._retire_record(
                            k, steps, nshr, recon_count, conv_h, stall_h)
                        continue
                    # gradient reconstruction + Eq. 9 over all samples
                    tr = time.perf_counter()
                    stale = np.flatnonzero(~self.act_m[k])
                    self._reconstruct(k, stale)
                    t_recon += time.perf_counter() - tr
                    recon_count[k] += 1
                    stats.reconstructions += 1
                    b_up, b_low = driver.betas(
                        self.gamma_m[k], self.alpha_m[k], Y[k],
                        float(Cs[k]))
                    if b_up + 2.0 * cfg.eps >= b_low:
                        live[k] = False
                        conv_h[k], stall_h[k] = True, False
                        retired[k] = self._retire_record(
                            k, steps, nshr, recon_count, conv_h, stall_h)
                    else:
                        unshrink.append(k)
            if not live.any():
                break

            if unshrink:
                # un-shrink: the affected problems re-activate every
                # sample, which forces the buffer back to the full set
                # (other problems keep their masks AND their shrink
                # countdowns). Per-lane policy: Single disables further
                # shrinking via the INT32_MAX sentinel; Multi re-arms the
                # counter at the full interval (scalar driver line-for-
                # line: next_shrink = step_save + interval).
                next_shrink = np.asarray(self.state.next_shrink, np.int64)
                for k in unshrink:
                    self.act_m[k, :] = True
                    conv_h[k] = False
                    stall_h[k] = False
                    if h.policy == "single":
                        shrink_act[k] = False
                        next_shrink[k] = _INT32_MAX
                    else:
                        next_shrink[k] = steps[k] + interval
                self._rebuild(np.arange(n), steps, nshr, live, conv_h,
                              stall_h, next_shrink)
            elif bool(summ.need_compact):
                # union compaction: every live shrink-enabled lane
                # experiences the scalar compaction reset (next_shrink =
                # step + max(1, min(interval, n_active))); disabled lanes
                # keep the sentinel
                next_shrink = np.asarray(self.state.next_shrink, np.int64)
                per_act = self.act_m.sum(axis=1)
                reset = live & shrink_act
                next_shrink = np.where(
                    reset,
                    steps + np.maximum(1, np.minimum(interval, per_act)),
                    next_shrink)
                keep = np.flatnonzero(self.act_m[live].any(axis=0))
                t_c = time.perf_counter()
                self._rebuild(keep, steps, nshr, live, conv_h, stall_h,
                              next_shrink)
                stats.compactions += 1
                stats.compact_time += time.perf_counter() - t_c
            else:
                # no geometry change: push the updated retirement flags
                # into the device lanes, everything else stays resident
                if done.any():
                    self.state = self.state._replace(
                        live=jnp.asarray(live))
            if cfg.checkpoint_dir:
                self._save_ckpt(steps, live, conv_h, stall_h, recon_count,
                                shrink_act, nshr)

        stats.iterations = int(steps.sum())
        stats.shrink_events = int(nshr.sum())
        stats.train_time = t_train
        stats.recon_time = t_recon
        stats.stalled = bool(stall_h.any())
        stats.converged = bool(conv_h.all())
        if self.cache is not None:
            stats.cache_hits = int(self.cache.hits)
            stats.cache_misses = int(self.cache.misses)
            looked = stats.cache_hits + stats.cache_misses
            stats.cache_hit_rate = (stats.cache_hits / looked
                                    if looked else 0.0)
        stats.per_problem = [r if r is not None else
                             self._retire_record(k, steps, nshr, recon_count,
                                                 conv_h, stall_h)
                             for k, r in enumerate(retired)]
        stats.total_time = time.perf_counter() - t0
        return self._finalize(stats)

    # -- batched internals -------------------------------------------------
    def _retire_record(self, k, steps, nshr, recon_count, conv_h, stall_h):
        return {"problem": int(k), "iterations": int(steps[k]),
                "converged": bool(conv_h[k]), "stalled": bool(stall_h[k]),
                "shrink_events": int(nshr[k]),
                "reconstructions": int(recon_count[k]),
                "n_sv": int(np.sum(self.alpha_m[k] > 0.0))}

    def _nshards(self) -> int:
        return int(np.prod(self.mesh.devices.shape)) if self.parallel else 1

    def _puts(self):
        """(data put, (K, m) stacked put): ``jnp.asarray`` single-device;
        mesh placement under ``parallel`` — buffer rows sharded, problem
        lanes replicated (the row axis is the data-parallel axis)."""
        if not self.parallel:
            return jnp.asarray, jnp.asarray
        from jax.sharding import NamedSharding, PartitionSpec as P
        axis = self.mesh.axis_names[0]
        rows_sh = NamedSharding(self.mesh, P(axis, None))
        vec_sh = NamedSharding(self.mesh, P(axis))
        stk_sh = NamedSharding(self.mesh, P(None, axis))

        def put_data(a):
            a = jnp.asarray(a)
            return jax.device_put(a, rows_sh if a.ndim == 2 else vec_sh)

        def put_stk(a):
            return jax.device_put(jnp.asarray(a), stk_sh)

        return put_data, put_stk

    def _build(self, rows, steps, next_shrink, nshr, live, conv, stall):
        """Host store -> one shared device buffer + stacked (K, M) state."""
        cfg, store = self.cfg, self.store
        Kp = self.Y.shape[0]
        p = self._nshards()
        m_per = mirror.full_m_per(rows.size, p, cfg.min_buffer)
        m = m_per * p
        K_buf = None
        if store.fmt == "ell":
            K_buf = (spfmt.bucket_lanes(store.buffer_K(rows), cfg.ell_lane,
                                        cap=store.K)
                     if cfg.ell_adaptive else store.K)
        buf = store.alloc(m, K_buf)
        sqb = np.zeros((m,), np.float32)
        idx_buf = np.full((m,), -1, np.int64)
        ystk = np.ones((Kp, m), np.float32)    # padding: y=+1, alpha=0 -> I1
        ab = np.zeros((Kp, m), np.float32)
        gb = np.full((Kp, m), np.inf, np.float32)
        actb = np.zeros((Kp, m), bool)
        for sl, sub in dataplane.deal(rows, p, m_per):
            store.fill(buf, sl, sub)
            sqb[sl] = store.sq_rows(sub)
            idx_buf[sl] = sub
            ystk[:, sl] = self.Y[:, sub]
            ab[:, sl] = self.alpha_m[:, sub]
            gb[:, sl] = self.gamma_m[:, sub]
            actb[:, sl] = self.act_m[:, sub]
        put_data, put_stk = self._puts()
        self.data = store.to_device(buf, put_data, gids=idx_buf, sq=sqb)
        self.ystk = put_stk(ystk)
        self.idx = idx_buf
        self.state = init_multi_state(
            put_stk(ab), put_stk(gb), put_stk(actb),
            steps, next_shrink, nshr, live)
        self.state = self.state._replace(
            converged=jnp.asarray(np.asarray(conv, bool)),
            stalled=jnp.asarray(np.asarray(stall, bool)))

    def _rebuild(self, rows, steps, nshr, live, conv, stall,
                 next_shrink):
        """Buffer rebuild (union compaction or un-shrink growth): rebuild
        from the masters and carry the row cache across by global id
        (column re-gather on compaction, wholesale drop on growth —
        trajectory-neutral either way: cached rows are exact). The caller
        must have synced device state into the masters ALREADY (the one
        per-dispatch :meth:`_writeback`); syncing here would overwrite
        host-reconstructed gamma for logically-shrunk rows that are still
        physically resident with the device's rotten copies. ``next_shrink``
        is per-lane: lanes not party to the triggering event keep their
        device countdown (a lane's shrink schedule must not be perturbed by
        ANOTHER problem's un-shrink — that was measurably
        trajectory-divergent vs the loop oracle)."""
        idx_old = self.idx
        self._build(rows, steps, next_shrink, nshr, live, conv, stall)
        self.cache = rowcache.remap_cache(self.cache, idx_old, self.idx)
        self._note_buffer()

    def _writeback(self):
        gids = self.idx
        good = gids >= 0
        cols = gids[good]
        self.alpha_m[:, cols] = np.asarray(self.state.alpha)[:, good]
        self.gamma_m[:, cols] = np.asarray(self.state.gamma)[:, good]
        self.act_m[:, cols] = np.asarray(self.state.active)[:, good]

    def _reconstruct(self, k: int, stale: np.ndarray):
        """Alg. 6 for problem k over global rows ``stale`` (host streaming
        backend — the store never materializes dense X for ELL)."""
        if stale.size == 0:
            return
        cfg = self.cfg
        sv_rows = np.flatnonzero(self.alpha_m[k] > 0.0)
        if sv_rows.size == 0:
            self.gamma_m[k, stale] = (-self.Y[k, stale]).astype(np.float32)
            return
        from repro.core import reconstruct
        self.gamma_m[k, stale] = reconstruct.reconstruct_gamma_store(
            cfg.kernel, self.store, self.Y[k], self.alpha_m[k], stale,
            cfg.inv_2s2, row_block=cfg.recon_block, sv_block=cfg.recon_block,
            ell_adaptive=cfg.ell_adaptive)

    def _note_buffer(self):
        self.stats.buffer_sizes.append(self.data.m)
        if isinstance(self.data, dataplane.ELLData):
            self.stats.buffer_K.append(self.data.K)

    # -- checkpointing -----------------------------------------------------
    # The (K, n) masters travel as ONE self-validating .npz: the payload
    # arrays' content checksum and the config fingerprint (K, n, format)
    # ride INSIDE the file, so a checkpoint never depends on sidecar
    # coherence. Saves are atomic (tmp + os.replace) and rotate the
    # previous file to multi_masters.prev.npz first, so resume always has
    # one known-good generation to fall back to when the newest save is
    # torn or corrupted. Like the scalar driver's checkpoints, the file
    # holds only host masters — no mesh or buffer state — so it restores
    # onto any device count.
    _CKPT_KEYS = ("alpha", "gamma", "active", "live", "converged",
                  "stalled", "recon_count", "shrink_act", "step",
                  "n_shrinks")

    def _ckpt_path(self) -> str:
        return os.path.join(self.cfg.checkpoint_dir, "multi_masters.npz")

    def _ckpt_prev_path(self) -> str:
        return os.path.join(self.cfg.checkpoint_dir,
                            "multi_masters.prev.npz")

    @classmethod
    def _ckpt_checksum(cls, payload: dict) -> str:
        from repro.ckpt import checkpoint as ck
        joined = "\n".join(f"{k}:{ck.array_sha(payload[k])}"
                           for k in cls._CKPT_KEYS)
        return hashlib.sha256(joined.encode()).hexdigest()

    def _save_ckpt(self, steps, live, conv, stall, recon_count, shrink_act,
                   nshr):
        from repro.ckpt import checkpoint as ck
        from repro.launch import chaos
        chaos.on_save(self._saves)
        self._saves += 1
        payload = dict(alpha=self.alpha_m, gamma=self.gamma_m,
                       active=self.act_m.astype(np.int8),
                       live=live.astype(np.int8),
                       converged=conv.astype(np.int8),
                       stalled=stall.astype(np.int8),
                       recon_count=recon_count,
                       shrink_act=shrink_act.astype(np.int8), step=steps,
                       n_shrinks=nshr)
        meta = dict(checksum=np.str_(self._ckpt_checksum(payload)),
                    format=np.str_(self.cfg.format))
        path, prev = self._ckpt_path(), self._ckpt_prev_path()

        def _write():
            os.makedirs(self.cfg.checkpoint_dir, exist_ok=True)
            tmp = path + ".tmp.npz"
            np.savez(tmp, **payload, **meta)
            if os.path.exists(path):
                os.replace(path, prev)
            os.replace(tmp, path)

        _, retries = ck.with_retries(_write,
                                     attempts=max(1, self.cfg.ckpt_retries),
                                     what=f"checkpoint save {path}")
        self.stats.ckpt_retries += retries

    def _read_ckpt(self, path: str, n: int, Kp: int):
        """Load + validate ONE checkpoint generation. IOError = corrupt
        (caller falls back); ValueError = config mismatch (caller error,
        never silently remapped)."""
        try:
            with np.load(path) as z:
                data = {k: np.array(z[k]) for k in z.files}
        except Exception as e:          # torn zip / short read / bad CRC
            raise IOError(f"unreadable checkpoint {path}: {e}") from e
        missing = [k for k in self._CKPT_KEYS if k not in data]
        if missing:
            raise IOError(f"checkpoint {path} is missing {missing}")
        if "checksum" in data and (
                str(data["checksum"]) != self._ckpt_checksum(data)):
            raise IOError(f"checkpoint {path} content checksum mismatch")
        if data["alpha"].shape != (Kp, n):
            raise ValueError(
                f"checkpoint shape {data['alpha'].shape} does not match "
                f"the requested (K, n) = {(Kp, n)}")
        if "format" in data and str(data["format"]) != self.cfg.format:
            raise ValueError(
                f"checkpoint {path} was saved with "
                f"format={str(data['format'])!r} but this fit has "
                f"format={self.cfg.format!r}")
        return (data["alpha"].astype(np.float32),
                data["gamma"].astype(np.float32),
                data["active"].astype(bool), data["live"].astype(bool),
                data["converged"].astype(bool),
                data["stalled"].astype(bool),
                data["recon_count"].astype(np.int64),
                data["shrink_act"].astype(bool),
                data["step"].astype(np.int64),
                data["n_shrinks"].astype(np.int64))

    def _load_ckpt(self, n: int, Kp: int):
        """Newest-first resume with one-generation fallback: a torn or
        corrupted multi_masters.npz falls back to the rotated .prev
        generation; only when every generation is unreadable does resume
        start fresh (with a warning)."""
        tried = False
        for path in (self._ckpt_path(), self._ckpt_prev_path()):
            if not os.path.exists(path):
                continue
            tried = True
            try:
                return self._read_ckpt(path, n, Kp)
            except IOError as e:
                warnings.warn(f"skipping corrupt checkpoint: {e}")
        if tried:
            warnings.warn("no readable multi-problem checkpoint "
                          "generation; starting fresh")
        return None

    # -- finalize ----------------------------------------------------------
    def _finalize(self, stats) -> list:
        cfg, store, Y, Cs = self.cfg, self.store, self.Y, self.Cs
        models = []
        for k in range(Y.shape[0]):
            alpha = self.alpha_m[k]
            gamma = self.gamma_m[k]
            Ck = float(Cs[k])
            b_up, b_low = driver.betas(gamma, alpha, Y[k], Ck)
            bnd = Ck * smo._BND
            i0 = (alpha > bnd) & (alpha < Ck - bnd)
            beta = (float(gamma[i0].mean()) if i0.any()
                    else float((b_low + b_up) / 2))
            sv = np.flatnonzero(alpha > 0)
            coef = (alpha[sv] * Y[k, sv]).astype(np.float32)
            cfg_k = dataclasses.replace(cfg, C=Ck)
            rec = stats.per_problem[k]
            rec["final_gap"] = float(b_low - b_up)
            rec["beta"] = beta
            rec["n_bound_sv"] = int(np.sum(alpha >= Ck))
            if store.fmt == "ell":
                sv_vals, sv_cols = store.ell_rows(sv)
                models.append(SVMModel(cfg_k, None, coef, beta, alpha,
                                       stats, sv_vals=sv_vals,
                                       sv_cols=sv_cols,
                                       n_features=store.n_features))
            else:
                models.append(SVMModel(cfg_k, store.X[sv].copy(), coef,
                                       beta, alpha, stats))
        stats.n_sv = int(sum(r["n_sv"] for r in stats.per_problem))
        stats.n_bound_sv = int(sum(r["n_bound_sv"]
                                   for r in stats.per_problem))
        stats.final_gap = float(max(r["final_gap"]
                                    for r in stats.per_problem))
        stats.mirror = "host"
        return models


def _aggregate_loop_stats(models: list) -> None:
    """Fold per-model FitStats of a sequential-loop fit into an aggregate
    view matching the batched backend's (attached to every model as
    ``per_problem`` on the FIRST model's stats, which callers treat as the
    run summary)."""
    if not models:
        return
    agg = models[0].stats
    agg.n_problems = len(models)
    agg.per_problem = [
        {"problem": k, "iterations": m.stats.iterations,
         "converged": m.stats.converged, "stalled": m.stats.stalled,
         "shrink_events": m.stats.shrink_events,
         "reconstructions": m.stats.reconstructions,
         "n_sv": m.stats.n_sv, "final_gap": m.stats.final_gap,
         "beta": m.beta}
        for k, m in enumerate(models)]


def _union_model(models: list) -> "SVMModel | None":
    """Union SV set + (n_sv_union, K) coefficient table + (K,) beta — the
    one-engine OvR serving artifact. Rows are keyed by global sample id
    (every model's ``alpha`` is the full (n,) master), coef is 0 where a
    row is not an SV of problem k, which pads exactly (a zero coefficient
    contributes nothing to any score)."""
    if not models:
        return None
    Kp = len(models)
    n = models[0].alpha.shape[0]
    union = np.flatnonzero(
        np.any(np.stack([m.alpha > 0.0 for m in models]), axis=0))
    if union.size == 0:
        return None
    coef = np.zeros((union.size, Kp), np.float32)
    for k, mdl in enumerate(models):
        a = mdl.alpha[union]
        # recover y on the union rows from each model's own SV coef sign;
        # rows that are not SVs of problem k keep coef 0
        pos = np.flatnonzero(mdl.alpha > 0.0)
        yk = np.zeros((n,), np.float32)
        yk[pos] = np.sign(mdl.sv_coef)
        coef[:, k] = a * yk[union]
    beta = np.asarray([m.beta for m in models], np.float32)
    m0 = models[0]
    # SV rows in the store's native format, scattered once into the union
    # layout (each model's SV block lands at its rows' union positions)
    if m0.sv_vals is not None:
        return _union_from_ell(models, union, coef, beta)
    sv_x = np.zeros((union.size, m0.sv_x.shape[1]), np.float32)
    for k, mdl in enumerate(models):
        pos = np.flatnonzero(mdl.alpha > 0.0)
        sel = np.searchsorted(union, pos)
        sv_x[sel] = mdl.sv_x
    return SVMModel(m0.config, sv_x, coef, beta, m0.alpha, m0.stats)


def _union_from_ell(models: list, union, coef, beta) -> "SVMModel | None":
    """ELL-format union model: scatter each model's (n_sv, K) SV payload
    into the union layout at the max lane budget."""
    m0 = models[0]
    Kl = max(int(m.sv_vals.shape[1]) if m.sv_vals.size else 0
             for m in models)
    Kl = max(Kl, 1)
    vals = np.zeros((union.size, Kl), np.float32)
    cols = np.zeros((union.size, Kl), np.int32)
    for mdl in models:
        pos = np.flatnonzero(mdl.alpha > 0.0)
        sel = np.searchsorted(union, pos)
        k = mdl.sv_vals.shape[1]
        vals[sel, :k] = mdl.sv_vals
        cols[sel, :k] = mdl.sv_cols
    return SVMModel(m0.config, None, coef, beta, m0.alpha, m0.stats,
                    sv_vals=vals, sv_cols=cols, n_features=m0.n_features)


def train_ovr(X, y, **kw) -> OvRSVMModel:
    """Convenience wrapper: one-vs-rest multi-class training over one
    resident store. ``backend`` (default 'batched') selects the fused
    multi-problem program or the sequential loop oracle."""
    backend = kw.pop("backend", "batched")
    return MultiProblemDriver(SVMConfig(**kw), backend=backend).fit_ovr(X, y)
