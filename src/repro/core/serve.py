"""Production inference plane: batched, sharded, low-latency SVM scoring.

Training (PRs 1-6) made the epoch cycle device-resident; this module does
the same for the *serving* side — ROADMAP item 4's "heavy traffic from
millions of users" path. A :class:`ServeEngine` holds a trained model's
support-vector set resident on device (dense or block-ELL, optionally
sharded over a mesh data axis exactly like the training mirror), accepts
dense or CSR query batches, pads every request to a power-of-two
microbatch bucket (``core/util.bucket_pow2`` — O(log) executables, not
one per batch size), and scores each bucket in ONE dispatch through the
row-provider layer's ``accumulate`` method: f(Z) = K(Z, SV) @ (alpha*y)
- beta, with the Pallas backend fusing the coef contraction into the
kernel-tile epilogue so the (B, M) kernel matrix never exists in HBM.

Dispatch timeline for one ``decision_function`` call (batch n, bucket b):

    host                         device (per bucket, ONE dispatch)
    ----                         ----------------------------------
    slice/densify queries   -->  [ qn = |z|^2                     ]
    pad to pow2 bucket b         [ for each SV tile (grid):       ]
                                 [   K-tile (bm, bq)  (MXU/VPU)   ]
                                 [   out += coef_tile @ K-tile    ]  } fused
                                 [ psum over shards (p > 1)       ]
    scores[s:s+take]        <--  [ out - beta                     ]
    ... next bucket (same executable whenever the pow2 bucket repeats)

The SV set is padded per shard to a lane multiple with ``coef = 0`` rows —
an *exact* pad (a zero coefficient contributes exactly 0 whatever the
padded row content), which is what lets one static shape serve every
model size in a bucket. bf16 SV storage (``dtype='bfloat16'``, or a
``model.compact(dtype='bfloat16')`` deployment artifact) halves the
resident bytes and the HBM stream; values are upcast to f32 on the way
into the kernel, so only the *storage* rounding (one bf16 quantization of
the SVs) separates bf16 scores from fp32 scores — the measured
exactness-vs-latency tradeoff in ``BENCH_serve.json``.

The single-device jnp fp32 engine is the serving parity baseline: it runs
``provider.matrix(Z) @ coef`` — the same compute
``SVMModel.decision_function_host`` (the seed-era host block loop, kept
as the oracle) performs — and ``tests/test_serve.py`` asserts the two
agree for every (format x backend x sharding) engine configuration.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import dataplane, kernel_fns, util
from repro.data import sparse as sp

__all__ = ["ServeEngine"]

_LANE = 128          # per-shard SV padding multiple (Pallas block floor)


def _csr_dense_block(csr: "sp.CSRMatrix", lo: int, hi: int,
                     out: np.ndarray) -> None:
    """Densify CSR rows [lo, hi) into the zeroed prefix of ``out``."""
    base = int(csr.indptr[lo])
    idx = np.arange(base, int(csr.indptr[hi]))
    counts = np.diff(csr.indptr[lo: hi + 1]).astype(np.int64)
    rows = np.repeat(np.arange(hi - lo), counts)
    out[rows, csr.indices[idx]] = csr.data[idx]


class ServeEngine:
    """Device-resident scoring engine for a trained :class:`SVMModel`.

    Parameters
    ----------
    model : SVMModel (duck-typed: config/beta/sv_coef + sv_x or
        sv_vals/sv_cols/n_features). ``model.compact()`` artifacts serve
        through the same engine.
    use_pallas : score buckets through the fused ``rbf_accumulate``
        Pallas kernels instead of the jnp ``matrix @ coef`` oracle path.
    shards : mesh width p; the SV axis is sharded over a 1-D data mesh
        (each device scores its SV block, one psum joins the partials).
        1 = single device (default), None = every visible device.
    dtype : SV value storage — 'float32' (default) or 'bfloat16'
        (half the resident bytes; upcast to f32 inside the dispatch).
        None inherits the model's own storage dtype.
    min_bucket / max_bucket : pow2 microbatch bucket clamp. Requests are
        chopped to ``max_bucket`` and padded up to ``min_bucket``, so at
        most log2(max/min)+1 executables exist per engine.
    """

    def __init__(self, model, *, use_pallas: bool = False,
                 shards: "int | None" = 1, dtype: "str | None" = None,
                 min_bucket: int = 64, max_bucket: int = 4096):
        cfg = model.config
        if shards is None:
            shards = len(jax.devices())
        if min_bucket <= 0 or max_bucket < min_bucket:
            raise ValueError(f"bad bucket range [{min_bucket}, {max_bucket}]")
        self.kernel = cfg.kernel
        self.inv_2s2 = float(cfg.inv_2s2)
        # (K,) beta + (n_sv, K) coef = multi-problem union engine (one-vs-
        # rest / grid): one resident union SV set scores K problems per
        # dispatch — f(Z) is (B, K) instead of (B,).
        beta = np.asarray(model.beta, np.float32).reshape(-1)
        self.multi = beta.size > 1 or \
            np.asarray(model.sv_coef).ndim == 2
        self.n_out = beta.size
        self.beta = beta if self.multi else float(beta[0])
        self.use_pallas = bool(use_pallas)
        self.shards = int(shards)
        self.min_bucket = int(min_bucket)
        self.max_bucket = int(max_bucket)
        self.fmt = "ell" if getattr(model, "sv_vals", None) is not None \
            else "dense"
        vals_dt = (model.sv_vals if self.fmt == "ell" else model.sv_x).dtype
        if dtype is None:
            dtype = str(vals_dt)
        if dtype in ("bf16", "bfloat16"):
            self.dtype = "bfloat16"
        elif dtype in ("float32", "fp32", "f32"):
            self.dtype = "float32"
        else:
            raise ValueError(f"unsupported SV storage dtype {dtype!r}")
        self._provider = kernel_fns.make_provider(
            self.kernel, self.fmt, self.use_pallas, self.inv_2s2)
        self._fns: dict[int, object] = {}
        self._mesh = None
        if self.shards > 1:
            from repro.core import parallel
            self._mesh = parallel.data_mesh(self.shards)
        self._build(model)

    # -- SV residency ------------------------------------------------------

    def _put(self, arr: np.ndarray, sharded_rows: bool = True):
        if self._mesh is None:
            return jnp.asarray(arr)
        from repro.core.parallel import AXIS
        spec = P(AXIS, *([None] * (arr.ndim - 1))) if sharded_rows else P()
        return jax.device_put(jnp.asarray(arr),
                              NamedSharding(self._mesh, spec))

    def _build(self, model) -> None:
        """Pad the SV set per shard to a lane multiple (coef-0 rows — an
        exact pad) and place it device-resident, sharded on the SV axis."""
        p = self.shards
        coef = np.asarray(model.sv_coef, np.float32)
        if self.multi:
            coef = coef.reshape(coef.shape[0], -1)      # (n_sv, K)
            assert coef.shape[1] == self.n_out, "coef/beta K mismatch"
        else:
            coef = coef.reshape(-1)
        self.n_sv = int(coef.shape[0])
        m_per = sp.round_lanes(max(1, -(-self.n_sv // p)), _LANE)
        m_pad = p * m_per
        self.m_pad = m_pad
        store_dt = np.float32 if self.dtype == "float32" else \
            np.dtype(jnp.bfloat16)
        rows = np.arange(self.n_sv)
        coef_p = np.zeros((m_pad, self.n_out) if self.multi else (m_pad,),
                          np.float32)
        for sl, sub in dataplane.deal(rows, p, m_per):
            coef_p[sl] = coef[sub]
        if self.fmt == "dense":
            sv = np.asarray(model.sv_x)
            self.n_features = int(sv.shape[1])
            x_p = np.zeros((m_pad, self.n_features), store_dt)
            for sl, sub in dataplane.deal(rows, p, m_per):
                x_p[sl] = sv[sub].astype(store_dt)
            sq = (x_p.astype(np.float32) ** 2).sum(axis=1)
            self._sv = (self._put(x_p),)
            self._K = 0
        else:
            vals = np.asarray(model.sv_vals)
            cols = np.asarray(model.sv_cols, np.int32)
            self.n_features = int(model.n_features)
            K = int(vals.shape[1])
            v_p = np.zeros((m_pad, K), store_dt)
            c_p = np.zeros((m_pad, K), np.int32)
            for sl, sub in dataplane.deal(rows, p, m_per):
                v_p[sl] = vals[sub].astype(store_dt)
                c_p[sl] = cols[sub]
            sq = (v_p.astype(np.float32) ** 2).sum(axis=1)
            self._sv = (self._put(v_p), self._put(c_p))
            self._K = K
        self._sq = self._put(sq.astype(np.float32))
        self._coef = self._put(coef_p)

    def _data(self, *sv_arrays):
        """Rebuild the provider's device view from (possibly bf16) storage;
        the f32 upcast happens inside the dispatch."""
        if self.fmt == "dense":
            (x,) = sv_arrays[:-1]
            return dataplane.DenseData(x.astype(jnp.float32), sv_arrays[-1])
        v, c = sv_arrays[:-1]
        return dataplane.ELLData(v.astype(jnp.float32), c, sv_arrays[-1],
                                 self.n_features)

    # -- bucket executables ------------------------------------------------

    def _make_fn(self, b: int):
        provider = self._provider
        beta = self.beta
        n_out = self.n_out

        if self.multi and self.use_pallas:
            # the fused Pallas accumulate kernels contract ONE coef column;
            # K columns unroll inside the same jitted program — still one
            # host dispatch per bucket, K kernel launches over a resident
            # SV set that is read from HBM per launch
            def _acc(data, Z, coef):
                return jnp.stack([provider.accumulate(data, Z, coef[:, j])
                                  for j in range(n_out)], axis=1)
        else:
            # jnp providers: matrix(Z) @ coef is (B, K) for a (M, K) table
            # in the SAME kernel-matrix pass a (M,) coef takes
            _acc = provider.accumulate

        def score(sv_and_sq, coef, Z):
            data = self._data(*sv_and_sq)
            return _acc(data, Z, coef) - beta

        if self._mesh is None:
            fn = jax.jit(score)
        else:
            from repro.core.parallel import AXIS
            from repro.launch.mesh import shard_map_compat

            def local(sv_and_sq, coef, Z):
                data = self._data(*sv_and_sq)
                part = _acc(data, Z, coef)
                return jax.lax.psum(part, AXIS) - beta

            fn = jax.jit(shard_map_compat(
                local, mesh=self._mesh,
                in_specs=(tuple(P(AXIS, *([None] * (a.ndim - 1)))
                                for a in (*self._sv, self._sq)),
                          P(AXIS, None) if self.multi else P(AXIS), P()),
                out_specs=P()))
        args = ((*self._sv, self._sq), self._coef)
        return lambda Z: fn(*args, Z)

    def _fn(self, b: int):
        if b not in self._fns:
            self._fns[b] = self._make_fn(b)
        return self._fns[b]

    # -- query plane -------------------------------------------------------

    def _bucket_of(self, remaining: int) -> int:
        return util.bucket_pow2(min(remaining, self.max_bucket),
                                self.min_bucket, self.max_bucket)

    def decision_function(self, Z) -> np.ndarray:
        """Scores for a dense (n, d) batch or CSR-like query input.

        The batch is chopped into pow2 buckets (large requests stream
        ``max_bucket`` chunks; the ragged tail pads up) and each bucket is
        one device dispatch. CSR queries are densified per bucket on the
        host — queries travel dense into the kernels in either case, so
        the ingest format never changes the scores.
        """
        csr = sp.as_csr(Z) if sp.is_csr_like(Z) else None
        if csr is not None:
            n, d = csr.shape
        else:
            Z = np.asarray(Z, np.float32)
            if Z.ndim == 1:
                Z = Z[None, :]
            n, d = Z.shape
        if d != self.n_features:
            raise ValueError(f"query dim {d} != model dim {self.n_features}")
        out = np.empty((n, self.n_out) if self.multi else (n,), np.float32)
        s = 0
        while s < n:
            b = self._bucket_of(n - s)
            take = min(n - s, b)
            zb = np.zeros((b, d), np.float32)
            if csr is not None:
                _csr_dense_block(csr, s, s + take, zb)
            else:
                zb[:take] = Z[s: s + take]
            out[s: s + take] = np.asarray(self._fn(b)(jnp.asarray(zb)))[:take]
            s += take
        return out

    def predict(self, Z) -> np.ndarray:
        if self.multi:
            raise ValueError(
                "multi-coef engine scores K problems; vote at the model "
                "level (OvRSVMModel.predict argmaxes decision_function)")
        return np.where(self.decision_function(Z) >= 0.0, 1.0,
                        -1.0).astype(np.float32)

    # -- introspection / pricing ------------------------------------------

    def memory_bytes(self) -> int:
        """Resident SV bytes (values + cols + sq + coef) across all shards."""
        total = 0
        for a in (*self._sv, self._sq, self._coef):
            total += a.size * a.dtype.itemsize
        return int(total)

    def describe(self) -> dict:
        return {
            "fmt": self.fmt, "dtype": self.dtype, "shards": self.shards,
            "n_out": self.n_out,
            "n_sv": self.n_sv, "m_pad": self.m_pad,
            "n_features": self.n_features, "use_pallas": self.use_pallas,
            "buckets": sorted(self._fns), "memory_bytes": self.memory_bytes(),
        }

    def model_flops(self, b: int) -> float:
        """Model FLOPs of one bucket dispatch: one kernel-row pass over the
        padded SV set per query plus the coef FMA epilogue (the same
        per-row terms ``dataplane.*Data.flops_row_pass`` charges)."""
        row_pass = 2.0 * self.n_features + 5.0 if self.fmt == "dense" \
            else 4.0 * self._K + 5.0
        return float(b) * self.m_pad * (row_pass + 2.0 * self.n_out)

    def roofline(self, b: "int | None" = None):
        """Price one bucket executable against hardware peak via
        ``launch/roofline.py`` term extraction (compute/HBM seconds per
        dispatch; ``useful_ratio`` = model FLOPs / HLO FLOPs). Always
        prices the aggregate single-chip-equivalent program (chips=1) —
        the jnp score over the full padded SV set — so fp32/bf16 and
        dense/ELL engines compare on one scale regardless of sharding.
        """
        from repro.launch import roofline as rl
        b = self.max_bucket if b is None else b
        provider, beta = self._provider, self.beta

        def whole(sv_and_sq, coef, Z):
            return provider.accumulate(self._data(*sv_and_sq), Z, coef) - beta

        compiled = jax.jit(whole).lower(
            tuple(jax.ShapeDtypeStruct(a.shape, a.dtype)
                  for a in (*self._sv, self._sq)),
            jax.ShapeDtypeStruct(self._coef.shape, self._coef.dtype),
            jax.ShapeDtypeStruct((b, self.n_features), jnp.float32)).compile()
        return rl.analyze(compiled, chips=1,
                          model_flops=self.model_flops(b),
                          bf16_model=(self.dtype == "bfloat16"))
