"""Training-time sample storage: the dense vs block-ELL data plane.

The paper stores training samples in CSR so large sparse datasets fit in
memory (Sec. 2.2, Fig. 1b). On TPU we use block-ELL instead (DESIGN.md §2):
every row is padded to a fixed nonzero budget K so the (vals, cols) tiles
stream through the VPU with lane-wise gathers. This module gives the SMO
driver one abstraction over both layouts:

  * device side — ``DenseData`` (X, sq_norms) and ``ELLData``
    (vals, cols, sq_norms): registered pytrees the jitted chunk runners
    consume directly. ``n_features`` on ``ELLData`` is static metadata so
    rows can be densified under jit (working-set rows travel dense; they
    are O(d) per iteration against O(M*K) for the gamma pass).
  * host side — ``DenseStore`` / ``ELLStore`` / ``CSRStore``: own the full
    training set in numpy and gather arbitrary row subsets into padded
    device buffers. This is what shrinking-driven physical compaction calls
    between chunks, so compaction moves ELL rows (2K+1 floats) instead of
    dense rows (d+1). ``CSRStore`` keeps the host copy in the paper's CSR
    layout (Fig. 1c) and streams CSR->ELL on every buffer fill, so a
    ``format='ell'`` fit never materializes a dense X on host.

The ELL lane budget K is *adaptive*: stores report ``buffer_K(rows)`` — the
max occupied-slot count over exactly the rows being gathered — and the
solver re-derives K at every physical compaction, bucketed to a power-of-two
number of ``lane``-wide groups (``data.sparse.bucket_lanes``) so the jit
cache sees O(log) distinct K values rather than one per compaction. Sample
elimination therefore shrinks *both* dimensions of the gamma-sweep hot loop:
rows (M_active) and lanes (K_active).

Memory rule of thumb: ELL wins whenever density < d / 2K — the paper's
Fig. 1b argument in vector-friendly form. With adaptive K the rule tracks
the *active set*: as easy samples (often the densest rows) are shrunk away,
K drops and the crossover moves toward denser datasets mid-run.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.data import sparse as sp


@dataclasses.dataclass(frozen=True)
class DenseData:
    """Device buffer, dense layout: (X, sq_norms).

    ``gids`` maps buffer position -> **global** sample id (-1 on padding
    rows) — the row-identity plumbing the kernel-row cache keys on and the
    device-compaction master scatter requires (global ids survive physical
    compaction; buffer positions do not). The epoch driver always threads
    it from ``idx_buf``; it is optional only for driver-external buffers
    (SV blocks in predict/reconstruction).
    """
    X: jax.Array          # (M, d) f32
    sq_norms: jax.Array   # (M,) f32 — precomputed ||x_i||^2
    gids: "jax.Array | None" = None   # (M,) i32 global row ids

    @property
    def m(self) -> int:
        return int(self.X.shape[0])

    @property
    def n_features(self) -> int:
        return int(self.X.shape[1])

    def dense_row(self, i) -> jax.Array:
        return self.X[i]

    def memory_bytes(self) -> int:
        return self.X.size * 4 + self.sq_norms.size * 4

    def flops_row_pass(self) -> float:
        """Model FLOPs of ONE kernel-row pass, per buffer row."""
        return 2.0 * self.n_features + 5.0

    def flops_per_row(self) -> float:
        """Model FLOPs of one fused two-row gamma update, per buffer row."""
        return 2.0 * self.flops_row_pass()


@dataclasses.dataclass(frozen=True)
class ELLData:
    """Device buffer, block-ELL layout: (vals, cols, sq_norms).

    Padding slots hold (val=0, col=0) and contribute exactly 0 to every
    gather-FMA; padding *rows* are all-padding (sq_norm 0). ``gids`` is the
    buffer-position -> global-sample-id map, always present on driver
    buffers (see ``DenseData``).
    """
    vals: jax.Array       # (M, K) f32
    cols: jax.Array       # (M, K) i32
    sq_norms: jax.Array   # (M,) f32
    n_features: int       # static: original feature dimension d
    gids: "jax.Array | None" = None   # (M,) i32 global row ids

    @property
    def m(self) -> int:
        return int(self.vals.shape[0])

    @property
    def K(self) -> int:
        return int(self.vals.shape[1])

    def dense_row(self, i) -> jax.Array:
        """Scatter one ELL row to dense (d,) — used for the O(d) working-set
        rows (z_up, z_low) each iteration; duplicated padding cols add 0."""
        return jnp.zeros((self.n_features,), jnp.float32) \
            .at[self.cols[i]].add(self.vals[i])

    def memory_bytes(self) -> int:
        return self.vals.size * 4 + self.cols.size * 4 + self.sq_norms.size * 4

    def flops_row_pass(self) -> float:
        """Model FLOPs of ONE gather-FMA kernel-row pass, per buffer row."""
        return 4.0 * self.K + 5.0

    def flops_per_row(self) -> float:
        # two gather-FMA passes over K slots + exp/FMA epilogue
        return 2.0 * self.flops_row_pass()


jax.tree_util.register_dataclass(
    DenseData, data_fields=["X", "sq_norms", "gids"], meta_fields=[])
jax.tree_util.register_dataclass(
    ELLData, data_fields=["vals", "cols", "sq_norms", "gids"],
    meta_fields=["n_features"])


class DenseStore:
    """Host-side dense training set; gathers row subsets into buffers."""
    fmt = "dense"

    def __init__(self, X: np.ndarray):
        self.X = np.ascontiguousarray(X, np.float32)
        self._sq: "np.ndarray | None" = None

    @property
    def n(self) -> int:
        return self.X.shape[0]

    @property
    def n_features(self) -> int:
        return self.X.shape[1]

    def buffer_K(self, rows: np.ndarray) -> int:
        """Dense buffers have no lane budget; kept for protocol uniformity."""
        return 0

    def sq_rows(self, rows: np.ndarray) -> np.ndarray:
        """||x_i||^2 for ``rows``, gathered from ONE store-level array.

        Every consumer of squared norms — buffer builds, un-shrink regrows,
        the device mirror, reconstruction SV blocks — gathers from this
        single precomputed (n,) array instead of re-summing per buffer.
        That makes a device-side gather of mirror ``sq_norms`` bitwise
        equal to a host rebuild by construction: the bits were fixed once
        at ingest, so no path depends on how a particular buffer shape
        groups the floating-point sum.
        """
        if self._sq is None:
            sq = np.empty((self.n,), np.float32)
            for s in range(0, self.n, 8192):
                b = self.X[s: s + 8192]
                sq[s: s + b.shape[0]] = (b * b).sum(axis=1)
            self._sq = sq
        return self._sq[rows]

    def alloc(self, m: int, K: "int | None" = None):
        return np.zeros((m, self.n_features), np.float32)

    def fill(self, buf, sl, rows: np.ndarray) -> None:
        buf[sl] = self.X[rows]

    def to_device(self, buf, put, gids: "np.ndarray | None" = None,
                  sq: "np.ndarray | None" = None) -> DenseData:
        if sq is None:
            sq = (buf * buf).sum(axis=1).astype(np.float32)
        g = None if gids is None else put(np.ascontiguousarray(gids, np.int32))
        return DenseData(put(buf), put(np.ascontiguousarray(sq, np.float32)),
                         g)

    def dense_rows(self, rows: np.ndarray) -> np.ndarray:
        return self.X[rows]


class _EllFamilyStore:
    """Shared buffer contract for stores that fill block-ELL device buffers.

    Subclasses provide ``lane``, ``row_extent`` (per-row occupied slots),
    ``n``, ``n_features``, ``K`` (the store-wide lane budget) and ``fill``;
    everything that defines the (vals, cols) buffer shape and its device
    form lives here so ELL and CSR host layouts cannot drift apart.

    ``fill(buf, sl, rows)`` must accept ``sl`` as either a slice or a
    row-index array (numpy subscript semantics): buffer builds pass
    contiguous shard slices, the ring-payload builder passes scattered SV
    positions.
    """
    fmt = "ell"

    def buffer_K(self, rows: np.ndarray) -> int:
        """Lane-rounded max occupied extent over exactly ``rows``."""
        k = int(self.row_extent[rows].max()) if rows.size else 0
        return sp.round_lanes(k, self.lane)

    def sq_rows(self, rows: np.ndarray) -> np.ndarray:
        """||x_i||^2 gathered from one store-level (n,) array (see
        ``DenseStore.sq_rows`` — the bit-stability argument is the same).
        Computed once by streaming store-K ELL blocks, so ``ELLStore`` and
        ``CSRStore`` produce identical bits for the same logical matrix."""
        if getattr(self, "_sq", None) is None:
            sq = np.empty((self.n,), np.float32)
            for s in range(0, self.n, 8192):
                rs = np.arange(s, min(s + 8192, self.n))
                vb, _ = self.ell_rows(rs, self.K)
                sq[s: s + rs.size] = (vb * vb).sum(axis=1)
            self._sq = sq
        return self._sq[rows]

    def alloc(self, m: int, K: "int | None" = None):
        K = self.K if K is None else int(K)
        return (np.zeros((m, K), np.float32), np.zeros((m, K), np.int32))

    def to_device(self, buf, put, gids: "np.ndarray | None" = None,
                  sq: "np.ndarray | None" = None) -> ELLData:
        vb, cb = buf
        if sq is None:
            sq = (vb * vb).sum(axis=1).astype(np.float32)
        g = None if gids is None else put(np.ascontiguousarray(gids, np.int32))
        return ELLData(put(vb), put(cb),
                       put(np.ascontiguousarray(sq, np.float32)),
                       self.n_features, g)

    def ell_rows(self, rows: np.ndarray, K: "int | None" = None):
        """(vals, cols) for ``rows`` at lane budget K (default: their own
        lane-rounded max extent) — SV extraction and ring payloads."""
        if K is None:
            K = self.buffer_K(rows)
        buf = self.alloc(rows.size, K)
        self.fill(buf, slice(0, rows.size), rows)
        return buf

    def dense_rows(self, rows: np.ndarray) -> np.ndarray:
        """Densify a row subset via a bounded ELL scratch block — Alg. 6
        reconstruction streams these, so sparse storage never forces a
        full dense materialization."""
        rows = np.asarray(rows).reshape(-1)
        vals, cols = self.ell_rows(rows)
        out = np.zeros((rows.size, self.n_features), np.float32)
        r = np.repeat(np.arange(rows.size), vals.shape[1])
        np.add.at(out, (r, cols.reshape(-1)), vals.reshape(-1))
        return out


class ELLStore(_EllFamilyStore):
    """Host-side block-ELL training set (vals, cols padded to K nonzeros).

    Rows pack their nonzeros into a slot prefix (``to_ell`` layout), so a
    subset whose max occupied extent is k can be gathered into a buffer of
    any K >= k by plain ``[:, :K]`` truncation — that is what makes per-
    buffer adaptive K a copy, not a repack.
    """

    def __init__(self, vals: np.ndarray, cols: np.ndarray, n_features: int,
                 lane: int = 128):
        self.vals = np.ascontiguousarray(vals, np.float32)
        self.cols = np.ascontiguousarray(cols, np.int32)
        self._n_features = int(n_features)
        self.lane = int(lane)
        self.row_extent = sp.ell_row_extent(self.vals)

    @property
    def n(self) -> int:
        return self.vals.shape[0]

    @property
    def n_features(self) -> int:
        return self._n_features

    @property
    def K(self) -> int:
        return self.vals.shape[1]

    def fill(self, buf, sl, rows: np.ndarray) -> None:
        vb, cb = buf
        K = vb.shape[1]
        if rows.size and int(self.row_extent[rows].max()) > K:
            raise ValueError(
                f"row extent {int(self.row_extent[rows].max())} exceeds "
                f"buffer K={K}")
        k = min(K, self.K)
        vb[sl, :k] = self.vals[rows, :k]
        cb[sl, :k] = self.cols[rows, :k]
        vb[sl, k:] = 0.0
        cb[sl, k:] = 0


class CSRStore(_EllFamilyStore):
    """Host-side CSR training set that fills block-ELL device buffers.

    The paper's storage format (Sec. 2.2, Fig. 1c) kept verbatim on host:
    (data, indices, indptr). Buffer fills stream CSR rows into the padded
    (vals, cols) layout on the fly, so ``SVMConfig(format='ell')`` can
    ingest datasets that never fit dense on host — the host cost is the
    CSR arrays plus one (m, K) buffer, never N*d. Produces the same
    ``ELLData`` device buffers as :class:`ELLStore` (``fmt`` says 'ell'
    because that is the *device* layout the chunk runners consume).

    ``K`` (explicit pin) mirrors dense ingest's ``ell_K``: when given, the
    store-wide lane budget is pinned to it instead of being derived from
    the data, so ``ell_adaptive=False`` refits keep stable trace shapes
    across datasets. Adaptive per-buffer K still contracts below the pin.
    """

    def __init__(self, csr: "sp.CSRMatrix", lane: int = 128,
                 K: "int | None" = None):
        self.csr = sp.as_csr(csr)
        self.lane = int(lane)
        # trailing-NONZERO extent, not stored-entry count: explicitly
        # stored zeros must not inflate K, and the device-side compaction
        # measures extents from the buffer values — the two must agree
        # (bit-identical buffer_K/shard_K trajectories)
        self.row_extent = sp.csr_row_extent(self.csr)
        self._K_pin = None if K is None else sp.round_lanes(K, self.lane)

    @property
    def n(self) -> int:
        return self.csr.shape[0]

    @property
    def n_features(self) -> int:
        return self.csr.shape[1]

    @property
    def K(self) -> int:
        if self._K_pin is not None:
            return self._K_pin
        k = int(self.row_extent.max()) if self.n else 0
        return sp.round_lanes(k, self.lane)

    def memory_bytes(self) -> int:
        return self.csr.memory_bytes()

    def fill(self, buf, sl, rows: np.ndarray) -> None:
        """Vectorized CSR->ELL gather of ``rows`` into the buffer slice."""
        vb, cb = buf
        if rows.size == 0:
            return
        K = vb.shape[1]
        nnz = self.row_extent[rows]
        if int(nnz.max()) > K:
            raise ValueError(f"row with {int(nnz.max())} nnz exceeds "
                             f"buffer K={K}")
        if self.csr.nnz == 0:        # all-padding rows; nothing to gather
            vb[sl] = 0.0
            cb[sl] = 0
            return
        take = self.csr.indptr[rows][:, None] + np.arange(K)[None, :]
        mask = np.arange(K)[None, :] < nnz[:, None]
        take = np.where(mask, take, 0)
        vb[sl] = self.csr.data[take] * mask
        cb[sl] = self.csr.indices[take] * mask


# --------------------------------------------------------------------------
# Device-side physical compaction (the shrink -> compact -> remap pipeline).
#
# The host stores above gather *initial* buffers (and un-shrink rebuilds,
# which re-add rows the buffer no longer holds). Physical compaction between
# chunks needs neither: every surviving row is already resident on device,
# so the gather is a ``jnp.take`` over the current buffer — no host numpy,
# no N*d (or M*2K) traffic through the PCIe/ICI host link. The driver reads
# back only the active count that fixes the new buffer shape (a scalar it
# already reads per chunk) and, for ELL, the (p,) per-shard surviving
# extents that fix the lane bucket; everything else stays on device.
#
# Bit-exactness contract: a device compaction must reproduce the host
# rebuild bit-for-bit (same row bits — buffers are copies of store rows;
# same packed-prefix truncation for ELL; gathered sq_norms instead of
# recomputed ones, which is bitwise safe because appending zero slots to a
# pairwise sum of squares cannot change the float). ``tests/test_driver.py``
# enforces this against the host path for every (format x solver) pair.


def ell_extents(vals: jax.Array) -> jax.Array:
    """Device analogue of :func:`data.sparse.ell_row_extent`: per-row
    occupied-slot count (last nonzero slot + 1; 0 for all-padding rows)."""
    nz = vals != 0.0
    K = vals.shape[1]
    return jnp.where(nz.any(axis=1),
                     K - jnp.argmax(nz[:, ::-1], axis=1), 0).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("p", "m_per"))
def ell_shard_extents(vals: jax.Array, keep: jax.Array, n_active: jax.Array,
                      *, p: int, m_per: int) -> jax.Array:
    """Per-shard max occupied extent of the surviving rows under the
    compaction re-layout, with the new per-shard slot count ``m_per``
    static. Kept as the shape-explicit reference implementation (and test
    oracle) for :func:`ell_shard_extents_dyn`, which the fused epoch
    runner uses in-dispatch — where ``m_per`` is a *traced* quantity and a
    reshape by it is impossible."""
    src, valid = compact_plan(keep, n_active, p, m_per)
    ext = jnp.where(valid, ell_extents(vals)[src], 0)
    return ext.reshape(p, m_per).max(axis=1)


def ell_shard_extents_dyn(vals: jax.Array, keep: jax.Array,
                          n_active: jax.Array, p: int) -> jax.Array:
    """Per-shard max occupied extent of the surviving rows — no static
    ``m_per``, so it can run inside the fused epoch dispatch whose summary
    carries the (p,) result back to the host (which buckets the max into
    the new lane budget and records ``FitStats.shard_K``).

    Shard membership under the balanced contiguous re-layout depends only
    on the survivor's global rank and ``n_active`` (shard ``q`` owns ranks
    ``[q*base + min(q, extra), ...)`` with ``base, extra = divmod(n_active,
    p)``), never on the per-shard padding ``m_per`` — so this computes the
    same values as :func:`ell_shard_extents` by a segment-max over the
    rank->shard map instead of a reshape. Integer-only arithmetic: exact.
    """
    ext = jnp.where(keep, ell_extents(vals), 0)
    rank = jnp.cumsum(keep.astype(jnp.int32)) - 1      # survivor rank per pos
    n_active = n_active.astype(jnp.int32)
    base = n_active // p
    extra = n_active - base * p
    cut = extra * (base + 1)
    # ranks below ``cut`` land in the first ``extra`` (base+1)-row shards;
    # the rest deal into base-row shards. base == 0 makes the second branch
    # dead (all ranks < n_active == cut); the max() only guards the div.
    q = jnp.where(rank < cut, rank // (base + 1),
                  extra + (rank - cut) // jnp.maximum(base, 1))
    q = jnp.where(keep, q, p)                          # drop non-survivors
    return jnp.zeros((p,), jnp.int32).at[q].max(ext, mode="drop")


def deal(idx: np.ndarray, p: int, m_per: int):
    """Host-side balanced contiguous dealing of rows ``idx`` over ``p``
    shards of ``m_per`` slots: yields ``(buffer_slice, rows)`` per shard
    (``base + (q < extra)`` rows each). This is the layout contract every
    buffer build shares — ``compact_plan`` is its jit-compatible device
    twin, and the two must stay interchangeable bit-for-bit."""
    base, extra = divmod(int(idx.size), p)
    off = 0
    for q in range(p):
        cnt = base + (1 if q < extra else 0)
        yield slice(q * m_per, q * m_per + cnt), idx[off: off + cnt]
        off += cnt


def full_layout(rows: np.ndarray, p: int, m_per: int):
    """Materialize the :func:`deal` layout: ``(idx, pos_of)`` with ``idx``
    (p*m_per,) mapping buffer position -> global id (-1 on per-shard
    padding tails) and ``pos_of`` (max_id+1,) the inverse. The ONE
    construction of the position map the mirror / host-ring / grow paths
    all share — the device==host bit-parity contract rides on them never
    disagreeing about where a row sits."""
    idx = np.full((p * m_per,), -1, np.int64)
    for sl, sub in deal(rows, p, m_per):
        idx[sl] = sub
    n = int(rows.max()) + 1 if rows.size else 0
    pos_of = np.full((n,), -1, np.int64)
    real = idx >= 0
    pos_of[idx[real]] = np.flatnonzero(real)
    return idx, pos_of


def compact_plan(keep: jax.Array, n_active: jax.Array, p: int, m_per: int):
    """Gather plan for the balanced contiguous re-layout (jit-compatible).

    ``keep`` (M_old,) bool marks surviving buffer rows; ``n_active`` is the
    same count as a traced scalar (the driver already reads it back to fix
    the static output shape ``p * m_per``). Survivors are enumerated in
    buffer-position order and dealt to ``p`` contiguous shards of
    ``base + (q < extra)`` rows — the exact layout the host rebuild
    produces, so the two paths are interchangeable mid-run. This is also
    the machinery mesh-portable checkpoint restore rides: the layout is a
    pure function of (surviving rows, p), so re-dealing a saved run onto
    a different device count is the same plan with a different p.

    Returns ``(src, valid)``: ``src`` (p*m_per,) old buffer positions to
    gather (arbitrary on padding rows), ``valid`` (p*m_per,) False on the
    per-shard padding tails.
    """
    M = keep.shape[0]
    rank = jnp.cumsum(keep) - 1                      # survivor rank per pos
    surv = jnp.zeros((M,), jnp.int32).at[
        jnp.where(keep, rank, M)].set(jnp.arange(M, dtype=jnp.int32),
                                      mode="drop")
    n_active = n_active.astype(jnp.int32)
    base = n_active // p
    extra = n_active - base * p
    j = jnp.arange(p * m_per, dtype=jnp.int32)
    q = j // m_per
    r = j % m_per
    valid = r < base + (q < extra).astype(jnp.int32)
    k = q * base + jnp.minimum(q, extra) + r
    src = surv[jnp.where(valid, k, 0)]
    return src, valid


def gather_rows(data, src: jax.Array, valid: jax.Array,
                K_new: "int | None" = None):
    """Gather surviving rows into a fresh (smaller) device buffer.

    ``src``/``valid`` come from :func:`compact_plan`. ELL buffers are
    truncated to the static lane budget ``K_new`` (always <= the current K:
    rows pack nonzeros into a slot prefix, so truncation is exact — the
    same ``[:, :K]`` copy the host stores rely on). Padding rows are zeroed
    (gids -1) to match the host ``alloc`` layout bit-for-bit.
    """
    gids = None
    if data.gids is not None:
        gids = jnp.where(valid, data.gids[src], -1)
    sq = jnp.where(valid, data.sq_norms[src], 0.0)
    if isinstance(data, DenseData):
        X = jnp.where(valid[:, None], data.X[src], 0.0)
        return DenseData(X, sq, gids)
    K = data.K if K_new is None else int(K_new)
    vals = jnp.where(valid[:, None], data.vals[src, :K], 0.0)
    cols = jnp.where(valid[:, None], data.cols[src, :K], 0)
    return ELLData(vals, cols, sq, data.n_features, gids)


def make_store(X, fmt: str, ell_K: "int | None" = None, ell_lane: int = 128):
    """Build the host store for ``fmt``.

    ``X`` may be a dense (n, d) matrix or — for ``fmt='ell'`` — CSR input
    (a ``data.sparse.CSRMatrix``, a scipy-like csr object, or a
    ``(data, indices, indptr, shape)`` tuple), which builds a
    :class:`CSRStore` without ever materializing a dense host matrix.
    An explicit ``ell_K`` is validated against ``ell_lane``: values that
    are not a whole number of lanes are rounded *up* (the Pallas tiling
    path requires lane-multiple K; silently passing a ragged K through
    used to reach the kernels unchecked).
    """
    if fmt == "dense":
        if sp.is_csr_like(X):
            X = sp.as_csr(X).to_dense()
        return DenseStore(X)
    if fmt == "ell":
        if ell_K is not None:
            if ell_K <= 0:
                raise ValueError(f"ell_K must be positive, got {ell_K}")
            ell_K = sp.round_lanes(ell_K, ell_lane)
        if sp.is_csr_like(X):
            store = CSRStore(sp.as_csr(X), lane=ell_lane, K=ell_K)
            if ell_K is not None and store.n and \
                    int(store.row_extent.max()) > ell_K:
                raise ValueError(
                    f"row with {int(store.row_extent.max())} nnz exceeds "
                    f"explicit ell_K={ell_K}")
            return store
        ell = sp.to_ell(np.asarray(X), K=ell_K, lane=ell_lane)
        return ELLStore(ell.vals, ell.cols, X.shape[1], lane=ell_lane)
    raise ValueError(f"unknown data format {fmt!r} (want 'dense' or 'ell')")
