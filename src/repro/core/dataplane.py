"""Training-time sample storage: the dense vs block-ELL data plane.

The paper stores training samples in CSR so large sparse datasets fit in
memory (Sec. 2.2, Fig. 1b). On TPU we use block-ELL instead (DESIGN.md §2):
every row is padded to a fixed nonzero budget K so the (vals, cols) tiles
stream through the VPU with lane-wise gathers. This module gives the SMO
driver one abstraction over both layouts:

  * device side — ``DenseData`` (X, sq_norms) and ``ELLData``
    (vals, cols, sq_norms): registered pytrees the jitted chunk runners
    consume directly. ``n_features`` on ``ELLData`` is static metadata so
    rows can be densified under jit (working-set rows travel dense; they
    are O(d) per iteration against O(M*K) for the gamma pass).
  * host side — ``DenseStore`` / ``ELLStore``: own the full training set in
    numpy and gather arbitrary row subsets into padded device buffers. This
    is what shrinking-driven physical compaction calls between chunks, so
    compaction moves ELL rows (2K+1 floats) instead of dense rows (d+1).

Memory rule of thumb: ELL wins whenever density < d / 2K — the paper's
Fig. 1b argument in vector-friendly form.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DenseData:
    """Device buffer, dense layout: (X, sq_norms)."""
    X: jax.Array          # (M, d) f32
    sq_norms: jax.Array   # (M,) f32 — precomputed ||x_i||^2

    @property
    def m(self) -> int:
        return int(self.X.shape[0])

    @property
    def n_features(self) -> int:
        return int(self.X.shape[1])

    def dense_row(self, i) -> jax.Array:
        return self.X[i]

    def memory_bytes(self) -> int:
        return self.X.size * 4 + self.sq_norms.size * 4

    def flops_per_row(self) -> float:
        """Model FLOPs of one fused two-row gamma update, per buffer row."""
        return 4.0 * self.n_features + 10.0


@dataclasses.dataclass(frozen=True)
class ELLData:
    """Device buffer, block-ELL layout: (vals, cols, sq_norms).

    Padding slots hold (val=0, col=0) and contribute exactly 0 to every
    gather-FMA; padding *rows* are all-padding (sq_norm 0).
    """
    vals: jax.Array       # (M, K) f32
    cols: jax.Array       # (M, K) i32
    sq_norms: jax.Array   # (M,) f32
    n_features: int       # static: original feature dimension d

    @property
    def m(self) -> int:
        return int(self.vals.shape[0])

    @property
    def K(self) -> int:
        return int(self.vals.shape[1])

    def dense_row(self, i) -> jax.Array:
        """Scatter one ELL row to dense (d,) — used for the O(d) working-set
        rows (z_up, z_low) each iteration; duplicated padding cols add 0."""
        return jnp.zeros((self.n_features,), jnp.float32) \
            .at[self.cols[i]].add(self.vals[i])

    def memory_bytes(self) -> int:
        return self.vals.size * 4 + self.cols.size * 4 + self.sq_norms.size * 4

    def flops_per_row(self) -> float:
        # two gather-FMA passes over K slots + exp/FMA epilogue
        return 8.0 * self.K + 10.0


jax.tree_util.register_dataclass(
    DenseData, data_fields=["X", "sq_norms"], meta_fields=[])
jax.tree_util.register_dataclass(
    ELLData, data_fields=["vals", "cols", "sq_norms"],
    meta_fields=["n_features"])


class DenseStore:
    """Host-side dense training set; gathers row subsets into buffers."""
    fmt = "dense"

    def __init__(self, X: np.ndarray):
        self.X = np.ascontiguousarray(X, np.float32)

    @property
    def n(self) -> int:
        return self.X.shape[0]

    @property
    def n_features(self) -> int:
        return self.X.shape[1]

    def alloc(self, m: int):
        return np.zeros((m, self.n_features), np.float32)

    def fill(self, buf, sl: slice, rows: np.ndarray) -> None:
        buf[sl] = self.X[rows]

    def to_device(self, buf, put) -> DenseData:
        sq = (buf * buf).sum(axis=1).astype(np.float32)
        return DenseData(put(buf), put(sq))

    def dense_rows(self, rows: np.ndarray) -> np.ndarray:
        return self.X[rows]


class ELLStore:
    """Host-side block-ELL training set (vals, cols padded to K nonzeros)."""
    fmt = "ell"

    def __init__(self, vals: np.ndarray, cols: np.ndarray, n_features: int):
        self.vals = np.ascontiguousarray(vals, np.float32)
        self.cols = np.ascontiguousarray(cols, np.int32)
        self._n_features = int(n_features)

    @property
    def n(self) -> int:
        return self.vals.shape[0]

    @property
    def n_features(self) -> int:
        return self._n_features

    @property
    def K(self) -> int:
        return self.vals.shape[1]

    def alloc(self, m: int):
        return (np.zeros((m, self.K), np.float32),
                np.zeros((m, self.K), np.int32))

    def fill(self, buf, sl: slice, rows: np.ndarray) -> None:
        vb, cb = buf
        vb[sl] = self.vals[rows]
        cb[sl] = self.cols[rows]

    def to_device(self, buf, put) -> ELLData:
        vb, cb = buf
        sq = (vb * vb).sum(axis=1).astype(np.float32)
        return ELLData(put(vb), put(cb), put(sq), self._n_features)

    def dense_rows(self, rows: np.ndarray) -> np.ndarray:
        """Densify a row subset (reconstruction streams bounded blocks, so
        ELL storage never forces a full dense materialization)."""
        out = np.zeros((rows.size, self._n_features), np.float32)
        r = np.repeat(np.arange(rows.size), self.K)
        np.add.at(out, (r, self.cols[rows].reshape(-1)),
                  self.vals[rows].reshape(-1))
        return out


def make_store(X: np.ndarray, fmt: str, ell_K: "int | None" = None,
               ell_lane: int = 128):
    """Build the host store for ``fmt`` from a dense sample matrix."""
    if fmt == "dense":
        return DenseStore(X)
    if fmt == "ell":
        from repro.data import sparse
        ell = sparse.to_ell(np.asarray(X), K=ell_K, lane=ell_lane)
        return ELLStore(ell.vals, ell.cols, X.shape[1])
    raise ValueError(f"unknown data format {fmt!r} (want 'dense' or 'ell')")
