"""Device-resident LRU cache for kernel rows (the row-provider fast path).

The paper recomputes every kernel row from scratch each iteration (Sec.
3.1.1 — "no kernel cache"), which keeps the distributed story simple but
leaves the dominant reuse pattern of SMO on the table: near convergence the
working set collapses onto a small set of hot samples, and the same
K(x_g, buffer) rows are requested over and over. This module adds the
classic complement to shrinking — a fixed-slot, jit-compatible kernel-row
cache — behind the row-provider layer (``kernel_fns.make_provider``), so
every chunk runner gets it without knowing the storage format or backend.

Layout
------
``RowCache`` is a pytree of statically-shaped arrays that lives in the
jitted chunk's ``while_loop`` carry (no host round-trips):

  * ``tags``  (S,)  i32 — **global** sample id cached in each slot, -1 empty;
  * ``vals``  (S, M) f32 — the cached rows K(x_tag, buffer) over the current
    buffer's M positions.  Under the parallel solver this table is sharded
    over the mesh on the M axis, so each shard caches exactly its own
    M_local row segment and every cache operation stays collective-free
    (lookups key on replicated global ids, so all shards take identical
    hit/miss branches and the tag table stays replicated by construction);
  * ``stamp`` (S,)  i32 — last-use tick per slot (recency eviction order);
  * ``seg``   (S,)  i32 — SLRU segment per slot (0 probationary /
    1 protected; identically 0 under the plain LRU policy);
  * ``tick/hits/misses`` — i32 scalars.

Slot count S is a trace dimension; the solver buckets it to a power of two
(``SVMConfig.row_cache_slots``) so user-tuned capacities do not multiply
the jit cache.

Eviction policies
-----------------
``policy='lru'`` (default) evicts the least-recently-used slot.  LRU has a
known pathology on cyclic access patterns: when the working set exceeds the
slot count, every access evicts the row that will be needed furthest in the
future and the hit rate collapses to zero.  ``policy='slru'`` (segmented
LRU, ``SVMConfig(row_cache_policy='slru')``) splits the slots into a
probationary and a protected segment (protected capacity S//2): rows enter
probationary on miss, are *promoted* to protected on their first hit, and
only probationary slots are eviction victims — so a one-shot scan can only
churn the probationary half while the re-referenced hot set survives in the
protected half.  Promotion past the protected capacity demotes the
protected LRU slot back to probationary (its value and stamp survive), so
the protected segment also ages.  Both policies only change *which* rows
stay cached — cached values are exact either way, so the bitwise
trajectory contract below holds for both.

Exactness
---------
Cached rows are exact values produced by the *same* provider kernels the
cache-off path runs, and the hit policy for the fused two-row gamma pass is
pairwise (serve from cache only when **both** rows are present, else
recompute both rows fused exactly as the cache-off path would): cache-on
and cache-off therefore produce bit-identical alpha/iteration trajectories.
That property is the core correctness test (``tests/test_rowcache.py``).

Invalidation-by-remap contract
------------------------------
A cached entry is a row over *buffer positions*, while its tag is a
*global* id — global ids survive physical compaction.  At every buffer
rebuild the solver calls :func:`remap_cache` with the old and new
``idx_buf`` (buffer position -> global sample id, -1 on padding):

  * **compaction** (new buffer rows are a subset of the old): every
    surviving column existed in the old buffer, so cached rows are
    *re-gathered* column-wise into the new geometry — the cache survives
    the shrink and keeps its hit history.  New padding columns are zeroed;
    that is safe because padding rows are never active, their gamma is
    pinned at +inf, and the writeback masks them out.
  * **reconstruction / un-shrink** (the buffer grows back): re-added
    positions have no cached values, so no entry can be *completed* by a
    gather — instead the cache survives by **rewarming**
    (:func:`regrow_cache`): every tagged slot's row is recomputed over the
    grown buffer with the exact in-loop compute islands, so tags, LRU/SLRU
    history and counters all carry across growth and the first post-growth
    accesses hit. (:func:`remap_cache` retains the old wholesale-drop
    behavior for callers that cannot rewarm.)

Checkpoints never store the cache: it is rebuilt empty on resume, which is
trajectory-neutral because cached rows are exact.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from repro.core import kernel_fns


POLICIES = ("lru", "slru")


class RowCache(NamedTuple):
    """Fixed-slot LRU/SLRU kernel-row cache (see module docstring)."""
    tags: jax.Array     # (S,) i32 global sample ids, -1 = empty slot
    vals: jax.Array     # (S, M) f32 cached rows over buffer positions
    stamp: jax.Array    # (S,) i32 last-use tick
    seg: jax.Array      # (S,) i32 SLRU segment (0 prob / 1 prot; 0 for lru)
    tick: jax.Array     # i32 — bumped once per cache access
    hits: jax.Array     # i32 — rows served from the value table
    misses: jax.Array   # i32 — rows (re)computed by the provider


def init_cache(slots: int, m: int,
               put_vals: Callable = jnp.asarray) -> RowCache:
    """Empty cache for a buffer of M positions. ``put_vals`` places the
    (S, M) value table — the parallel solver shards it over the mesh."""
    return RowCache(
        tags=jnp.full((slots,), -1, jnp.int32),
        vals=put_vals(np.zeros((slots, m), np.float32)),
        stamp=jnp.zeros((slots,), jnp.int32),
        seg=jnp.zeros((slots,), jnp.int32),
        tick=jnp.int32(0),
        hits=jnp.int32(0),
        misses=jnp.int32(0),
    )


def bucket_slots(slots: int) -> int:
    """Power-of-two slot bucketing (>= 2) — capacity is a shape dimension of
    every cached chunk runner, so arbitrary user values must not each get
    their own XLA executable."""
    s = max(2, int(slots))
    return 1 << (s - 1).bit_length()


def _find(tags: jax.Array, gid: jax.Array):
    present = tags == gid
    return jnp.argmax(present), jnp.any(present)


# Only the (M,)/(M, 2) row values ever cross a ``lax.cond`` boundary below:
# XLA copies conditional operands/results, so letting the cond touch the
# whole cache pytree (the (S, M) value table in particular) adds an O(S*M)
# copy per iteration — measured at ~50% of the hot-loop time. Hence the
# candidate slots are *gathered* unconditionally before the cond (two O(M)
# reads), the cond chooses between the gathered rows and the provider
# compute, and the tag/stamp/value writes happen unconditionally with
# ``where``-selected slots; on a hit they rewrite the slot with its own
# (bit-identical) row, which XLA performs as one O(M) dynamic-update-slice
# on the loop-carried buffer.

_STAMP_MAX = jnp.int32(2**31 - 1)


def _write(c: RowCache, gid, present, slot_e, row,
           policy: str = "lru") -> tuple:
    """Write ``row`` under ``gid``: its existing slot when present, else the
    policy's eviction victim. Returns (cache, slot).

    ``lru``: victim = least-recently-used slot; ``seg`` untouched (all 0).
    ``slru``: victim = least-recently-used *probationary* slot (protected
    slots are never evicted by an insert — that is the scan resistance);
    a hit promotes its slot to protected, demoting the protected LRU back
    to probationary when the protected segment is at capacity (S // 2).
    The invariant |protected| <= S // 2 < S guarantees a probationary
    victim always exists.
    """
    if policy == "lru":
        slot = jnp.where(present, slot_e, jnp.argmin(c.stamp))
        seg = c.seg
    else:
        prot = c.seg == 1
        victim = jnp.argmin(jnp.where(prot, _STAMP_MAX, c.stamp))
        slot = jnp.where(present, slot_e, victim)
        cap = c.tags.shape[0] // 2
        need_demote = present & (c.seg[slot] == 0) \
            & (jnp.sum(prot.astype(jnp.int32)) >= cap)
        dslot = jnp.argmin(jnp.where(prot, c.stamp, _STAMP_MAX))
        seg = c.seg.at[dslot].set(
            jnp.where(need_demote, 0, c.seg[dslot]))
        seg = seg.at[slot].set(jnp.where(present, 1, 0))
    return c._replace(
        tags=c.tags.at[slot].set(gid),
        vals=c.vals.at[slot].set(row),
        stamp=c.stamp.at[slot].set(c.tick),
        seg=seg), slot


def get_row(cache: RowCache, gid: jax.Array, compute: Callable[[], jax.Array],
            policy: str = "lru", live=None):
    """One row by global id: cached value on hit, ``compute()`` on miss.
    ``compute`` must be shard-local (it runs inside ``lax.cond``, where a
    collective would not be legal). Returns (row, cache).

    ``live`` (optional traced bool) gates the hit/miss *counters* only: the
    batched multi-problem runner keeps retired problems issuing their last
    row request every iteration (so the access pattern stays trace-static —
    a ``lax.cond`` around the access would copy the (S, M) value table, see
    above) but must not let those idle repeats pollute the statistics the
    driver bills FLOPs from. Values/evictions are unaffected: an idle
    repeat re-serves (and re-inserts) bit-identical rows."""
    cache = cache._replace(tick=cache.tick + 1)
    slot, hit = _find(cache.tags, gid)
    got = cache.vals[slot]                              # O(M), pre-cond
    row = lax.cond(hit, lambda: got, compute)
    cache, _ = _write(cache, gid, hit, slot, row, policy)
    w = jnp.int32(1) if live is None else live.astype(jnp.int32)
    return row, cache._replace(
        hits=cache.hits + hit.astype(jnp.int32) * w,
        misses=cache.misses + (~hit).astype(jnp.int32) * w)


def get_pair(cache: RowCache, gid2: jax.Array,
             compute2: Callable[[], jax.Array], policy: str = "lru",
             live=None):
    """The fused two-row access of Eq. 6: returns ((M, 2) rows, cache).

    Pairwise hit policy: the value table is consulted only when *both*
    global ids are present; any miss recomputes both rows with the fused
    two-row provider kernel (one HBM pass — exactly the cache-off path)
    and inserts each row separately, so later pairs can hit on rows that
    were produced by different iterations. This keeps cache-on bit-exact
    against cache-off while still amortizing the dominant pair-repeat
    pattern of late-stage SMO.

    ``live`` gates the hit/miss counters exactly as in :func:`get_row`.
    """
    cache = cache._replace(tick=cache.tick + 1)
    s0, h0 = _find(cache.tags, gid2[0])
    s1, h1 = _find(cache.tags, gid2[1])
    both = h0 & h1
    got = jnp.stack([cache.vals[s0], cache.vals[s1]], axis=1)  # O(M), pre-cond
    rows = lax.cond(both, lambda: got, compute2)               # (M, 2)
    cache, slot0 = _write(cache, gid2[0], h0, s0, rows[:, 0], policy)
    # re-probe against the updated tags so gid2[1] == gid2[0] (or a fresh
    # insert colliding with s1's stamp) resolves to the right slot
    s1b, h1b = _find(cache.tags, gid2[1])
    cache, _ = _write(cache, gid2[1], h1b, s1b, rows[:, 1], policy)
    two = jnp.int32(2) if live is None else 2 * live.astype(jnp.int32)
    return rows, cache._replace(
        hits=cache.hits + jnp.where(both, two, 0),
        misses=cache.misses + jnp.where(both, 0, two))


def make_accessors(provider, data, cached: bool, never: jax.Array,
                   policy: str = "lru"):
    """The runners' row-access functions, cached and uncached — ONE
    implementation because the exact barrier/cond structure is load-bearing
    for the bitwise cache-on == cache-off contract:

      * input/output ``optimization_barrier``s stop the row epilogue from
        being duplicated into consumer fusions with context-dependent FMA
        contraction (observed: 1-ulp row drift between runner variants);
      * the uncached path wraps the compute in a degenerate runtime-false
        ``lax.cond`` (``never`` must be a traced False, e.g. ``tol < 0``),
        because the cached path computes rows inside its hit/miss cond and
        XLA CPU codegens branch regions separately from the main region
        (observed: 1-ulp exp drift between the same row computed in-branch
        vs top-level).

    ``data`` is the device buffer (``DenseData``/``ELLData``, or the
    shard-local view under shard_map) the accessors close over. Returns
    ``(get_row1(cache, gid, z), get_rows2(cache, gid2, z2))``, each giving
    ``(rows, cache)``; pass ``gid``/``gid2`` = None when ``cached`` is
    False. The optional ``live`` keyword of each accessor gates the cache
    counters (see :func:`get_row`); the single-problem runners leave it
    None.
    """
    def get_row1(c, gid, z, live=None):
        # Single rows go through the duplicated-query rows2 GEMM
        # (kernel_fns.row_via_rows2) rather than provider.row: the GEMV is
        # not context-stable on XLA CPU, the GEMM is — which is what lets
        # the wss2 cache be rewarmed across un-shrink with the exact bits
        # an in-loop miss would produce (see warm_vals).
        compute = lambda: kernel_fns.row_via_rows2(provider, data, z)
        if cached:
            return get_row(c, gid, compute, policy, live=live)
        zero = jnp.zeros_like(data.sq_norms)
        return lax.cond(never, lambda: zero, compute), c

    def get_rows2(c, gid2, z2, live=None):
        compute = lambda: lax.optimization_barrier(
            provider.rows2(data, lax.optimization_barrier(z2)))
        if cached:
            return get_pair(c, gid2, compute, policy, live=live)
        zero = jnp.zeros(data.sq_norms.shape + (2,), jnp.float32)
        return lax.cond(never, lambda: zero, compute), c

    return get_row1, get_rows2


def tag_queries(data, tags: jax.Array, n: int) -> jax.Array:
    """Dense (S, d) query rows for the cached tags, gathered from the
    buffer by global id (jit-compatible). Every tag must be resident in
    ``data`` — true at un-shrink, where the buffer is the full set. Bits
    match the in-loop ``data.dense_row`` queries exactly (the ELL
    scatter-add is exact: one real entry per column plus zeros)."""
    m = data.gids.shape[0]
    inv = jnp.zeros((n + 1,), jnp.int32).at[
        jnp.where(data.gids >= 0, data.gids, n)].set(
        jnp.arange(m, dtype=jnp.int32))
    tpos = inv[jnp.clip(tags, 0, n)]
    return jax.vmap(data.dense_row)(tpos)


def warm_vals(provider, data, zq: jax.Array, tags: jax.Array,
              never: jax.Array, pairs: bool) -> jax.Array:
    """Recompute the (S, M) value table over ``data`` for the tagged slots.

    This is what lets the row cache SURVIVE un-shrink growth instead of
    being invalidated wholesale: a grown buffer re-adds columns no cached
    entry has values for, so surviving exactly means recomputing each
    tagged row over the new buffer — with the *same* barrier +
    degenerate-cond compute islands the chunk runners' miss path uses
    (``make_accessors``), so a later hit serves bits identical to what an
    in-loop miss would have produced and the cache-on == cache-off
    trajectory contract holds across growth. ``pairs`` selects the fused
    two-row kernel (wss1 caches rows produced by ``rows2``) vs the
    single-row kernel (wss2 caches rows produced by ``row``); slots are
    walked in (0,1),(2,3),... pairs — partner choice cannot change a
    column's bits (the position-symmetric ``ell_dots2`` / independent
    GEMM columns the pairwise hit policy already relies on). Untagged
    slots are zeroed; tags/stamps/segments/counters are untouched.
    Shard-local (no collectives), so the parallel solver can run it
    under shard_map on the local buffer view.

    Context stability: single-row GEMV computes were *measured* to drift
    by ulps between loop and standalone contexts on XLA CPU even behind
    barrier/cond islands, which used to force wholesale invalidation of
    the wss2 cache at un-shrink. Resolved by producing single rows
    through the duplicated-query rows2 GEMM (``kernel_fns.row_via_rows2``
    — the context-stable, position-symmetric shape): the non-``pairs``
    path here and the in-loop wss2 miss path (``make_accessors``) share
    that one helper, so wss2 now rewarms exactly like wss1.
    """
    m = data.sq_norms.shape[0]
    S = tags.shape[0]
    if pairs:
        def step(c, sl):
            z2 = zq[sl]                                       # (2, d)
            compute = lambda: lax.optimization_barrier(
                provider.rows2(data, lax.optimization_barrier(z2)))
            rows = lax.cond(
                never, lambda: jnp.zeros((m, 2), jnp.float32), compute)
            return c, rows.T                                  # (2, m)
        _, out = lax.scan(step, 0,
                          jnp.arange(S, dtype=jnp.int32).reshape(S // 2, 2))
        vals = out.reshape(S, m)
    else:
        def step(c, s):
            # same compute island as the in-loop wss2 miss path
            compute = lambda: kernel_fns.row_via_rows2(provider, data, zq[s])
            row = lax.cond(
                never, lambda: jnp.zeros((m,), jnp.float32), compute)
            return c, row
        _, vals = lax.scan(step, 0, jnp.arange(S, dtype=jnp.int32))
    return jnp.where((tags >= 0)[:, None], vals, 0.0)


@functools.partial(jax.jit, static_argnames=("provider", "pairs", "n"))
def _regrow_step(cache: RowCache, data, never, *, provider, pairs, n):
    zq = tag_queries(data, cache.tags, n)
    return cache._replace(
        vals=warm_vals(provider, data, zq, cache.tags, never, pairs))


def regrow_cache(cache: Optional[RowCache], data, provider, pairs: bool,
                 n: int) -> Optional[RowCache]:
    """Single-host cache carry-over across un-shrink growth: rewarm every
    tagged slot against the grown (full-set) buffer in one jitted pass.
    See :func:`warm_vals`; the parallel solver wraps the same scan in
    shard_map (``parallel.ParallelSMOSolver._regrow_cache``)."""
    if cache is None:
        return None
    return _regrow_step(cache, data, jnp.asarray(False), provider=provider,
                        pairs=pairs, n=n)


def remap_cache_device(cache: Optional[RowCache], src: jax.Array,
                       valid: jax.Array) -> Optional[RowCache]:
    """Device-side cache carry-over across a *physical compaction* — the
    jit-compatible half of the invalidation-by-remap contract.

    ``src``/``valid`` are the compaction gather plan
    (``dataplane.compact_plan``): the new buffer is a subset of the old, so
    every cached row survives by a column re-gather (``jnp.take`` along the
    value table's M axis), tags/stamps/segments/counters untouched. New
    padding columns are zeroed (padding rows are never active, so the zeros
    are never read as kernel values). No host materialization of the
    (S, M) table — under the parallel solver the gather is compiled
    alongside the buffer gather, so XLA reshards the mesh-sharded table in
    the same step. Buffer *growth* (reconstruction / un-shrink) still goes
    through the host :func:`remap_cache`, which invalidates wholesale.
    """
    if cache is None:
        return None
    vals = jnp.where(valid[None, :], jnp.take(cache.vals, src, axis=1), 0.0)
    return cache._replace(vals=vals)


def remap_cache(cache: Optional[RowCache], old_idx: np.ndarray,
                new_idx: np.ndarray,
                put_vals: Callable = jnp.asarray) -> Optional[RowCache]:
    """Host-side cache carry-over across a buffer rebuild (see module
    docstring): re-gather value columns when the new buffer is a subset of
    the old one (compaction under ``compact_backend='host'``), invalidate
    wholesale when it is not (reconstruction / un-shrink re-adds rows with
    no cached values).

    ``old_idx`` / ``new_idx`` are the driver's ``idx_buf`` arrays mapping
    buffer position -> global sample id (-1 on padding rows).
    """
    if cache is None:
        return None
    slots = int(cache.tags.shape[0])
    old_idx = np.asarray(old_idx, np.int64)
    new_idx = np.asarray(new_idx, np.int64)
    m_new = int(new_idx.size)
    new_real = new_idx >= 0
    old_real = old_idx >= 0
    fresh = init_cache(slots, m_new, put_vals)
    # tags are O(slots) — check them before touching the O(slots * M)
    # value table, so an empty/just-invalidated cache never pays the
    # device->host->device round-trip of the column gather below
    if not new_real.any() or not old_real.any() \
            or (np.asarray(cache.tags) == -1).all():
        return fresh._replace(hits=cache.hits, misses=cache.misses,
                              tick=cache.tick)
    hi = int(max(old_idx.max(), new_idx.max())) + 1
    pos = np.full((hi,), -1, np.int64)
    pos[old_idx[old_real]] = np.flatnonzero(old_real)
    src = pos[new_idx[new_real]]
    if (src < 0).any():
        # grown buffer: rows with no cached column anywhere -> invalidate
        return fresh._replace(hits=cache.hits, misses=cache.misses,
                              tick=cache.tick)
    vals = np.asarray(cache.vals)
    new_vals = np.zeros((slots, m_new), np.float32)
    new_vals[:, new_real] = vals[:, src]
    return cache._replace(vals=put_vals(new_vals))
