"""Epoch driver — the paper's Algorithm 5 control flow, exactly once.

This module owns the shrink -> compact -> reconstruct -> un-shrink ->
re-optimize state machine that both :class:`repro.core.solver.SMOSolver`
and :class:`repro.core.parallel.ParallelSMOSolver` train through. The
solvers supply a small hook surface (runner construction, device
placement, Alg. 6 reconstruction); the Single/Multi policy logic,
checkpoint/resume, physical compaction, and stats accounting live here
and nowhere else.

The PROBLEM axis: everything below is written for ONE binary subproblem
(one alpha/gamma pair over one buffer). When K related subproblems share
the training set — one-vs-rest classes, a C grid —
:class:`repro.core.multi.MultiProblemDriver` batches them onto a leading
(K,) lane axis over the same resident store instead of looping this
driver K times: one joint dispatch loop, per-problem convergence and
retirement inside it, per-problem logical shrinking with union physical
compaction, and kernel-row production amortized across problems through
the shared row cache. Per problem the trajectory is bit-identical to
this driver run alone (``MultiProblemDriver(backend='loop')`` is exactly
that oracle), so the state machine documented here is also the spec for
each lane of the batched runner.

Phases (faithful to Alg. 5):

  shrink stage    run jitted SMO chunks with in-loop shrinking until
                  beta_up + 20*eps >= beta_low on the active set;
                  physically compact the buffer between chunks when enough
                  samples have been shrunk;
  reconstruct     Alg. 6 for every non-active sample, then un-shrink
                  (reset pi_q) and re-check optimality over ALL samples;
  re-optimize     Single: shrinking disabled, run to 2*eps.
                  Multi:  shrinking re-enabled (counter reset), run to
                          2*eps on the active set, reconstruct again,
                          repeat until Eq. 9 holds over all samples.

The "Original" baseline (Alg. 3, no shrinking) is the same driver with the
shrink interval = 0 and no reconstruction, run straight to 2*eps.

Fused-epoch dispatch loop
-------------------------
The optimization hot loop is NOT an iteration loop: ``fit`` dispatches
*fused epochs* (``smo.make_chunk_runner`` / the shard_map twin in
``parallel``) of up to ``SVMConfig.fuse_iters`` segments each — a segment
being one legacy chunk of up to ``chunk_iters`` SMO iterations — and the
only thing it reads back per dispatch is the fixed-size
``smo.EpochSummary``. Everything the host used to sync scalars for
(convergence, stall, iteration budget, the compaction predicate, the
shrink counter, cache hit/miss counters, the (p,) ELL shard extents of a
pending compaction) is evaluated on device between segments and rides
that one summary; host<->device traffic per dispatch is O(p), not O(n),
and there are exactly zero per-iteration readbacks. See the dispatch
timeline diagram in ``smo.py``.

Host decisions happen only at dispatch boundaries, on summary fields:

  * hard exit     summary.converged | stalled | step >= max_iters;
  * compaction    summary.need_compact -> the host buckets the new
                  geometry from summary.n_active (+ summary.shard_ext for
                  the ELL lane budget) and dispatches the jitted compact
                  step — no separate extent-scan dispatch;
  * checkpoints   the segment budget of each dispatch is clipped to the
                  checkpoint cadence (``heuristics.fuse_budget``), so a
                  k-fused run saves at exactly the same iteration counts
                  as the ``fuse_iters=1`` oracle;
  * Eq. 9 check   at reconstruction points, both bounds come from ONE
                  jitted reduction (``betas``) — a (2,) readback, not the
                  full (n,) gamma; in device-mirror mode it runs directly
                  on the (n,) device masters, and host gamma is
                  materialized only at checkpoints and fit exit.

``fuse_iters=1`` (default) runs one segment per dispatch on the SAME XLA
executable as any k > 1 (all schedule scalars are traced), which makes it
the bit-exact parity oracle the fused-epoch tests diff against.

Device-resident epoch cycle
---------------------------
Physical compaction is a *device-side* operation by default
(``SVMConfig(compact_backend='device')``): one jitted step gathers the
surviving rows (and their gids, squared norms, and — truncating the lane
budget — ELL slots) with ``jnp.take`` over the current buffer, re-gathers
the row-cache value table by the same plan, and scatters the outgoing
buffer's alpha/gamma into device-resident (n,) master arrays so dropped
rows keep their drop-time values without a host round-trip. Input buffers
are donated, so peak memory stays ~1 buffer. The host reads back only the
active count (a scalar it already reads every chunk; fixes the new buffer
shape) and, for ELL, the (p,) per-shard surviving extents (fix the lane
bucket and ``FitStats.shard_K``); row data and cache values never cross
the host link. The
``'host'`` backend keeps the store-rebuild path (numpy gather + re-upload)
— bit-identical by construction, kept as the parity oracle for tests and
the compaction benchmark.

The OTHER two host round-trips of the epoch cycle — Alg. 6 gradient
reconstruction (the paper's named bottleneck, Sec. 3.4) and un-shrink
buffer growth — go through the device-resident full-set mirror
(``repro.core.mirror``; ``SVMConfig(mirror='auto'|'device'|'host')``,
sized at fit time with 'auto' falling back to host streaming when it
will not fit). With the mirror active, reconstruction is one jitted
``lax.scan`` over mirror SV/query blocks accumulating into the donated
(n,) gamma master (``_reconstruct_step``; only index vectors and the
(n,) gamma needed by the host-side Eq. 9 check cross the link), and
every buffer (re)build — initial, resume subset, un-shrink growth — is
a device gather from the mirror + the alpha/gamma masters
(``_grow_step``).

Fault tolerance and elasticity
------------------------------
The save boundary IS the dispatch boundary IS the restore boundary:

    dispatch ─▶ summary ─▶ [checkpoint?] ─▶ dispatch ─▶ ...
                               │ atomic step_{N}/ (host (n,) masters
                               ▼  + active/membership masks + config
                          crash/rescale   meta — no mesh/layout state)
                               │ resume: newest COMPLETE step
                               ▼  (torn/corrupt dirs skipped)
                re-deal the saved row set for the CURRENT device
                count, rebuild the cache empty, re-enter mid-schedule

so a run killed at ANY point loses at most the dispatches since the
last save, and a checkpoint saved under N devices restores onto M: the
balanced buffer layout, ELL lane budgets and mirror geometry are pure
functions of (row set, p) recomputed at build time, never part of
the checkpoint (see the recovery diagram at the fault-tolerance section
below, ``launch/elastic.py`` for the watchdog/rescale utilities, and
``launch/chaos.py`` for the fault-injection hooks this loop honors at
its dispatch/save boundaries). ``SVMConfig(watchdog_threshold=...)``
arms a straggler watchdog over the per-dispatch wall times; a flagged
dispatch forces a checkpoint and halves the fused segment budget. The host-streaming reconstruction
(``reconstruct.reconstruct_gamma_store`` / the parallel ring fed from
host-built arrays) and host store rebuilds survive under
``mirror='host'`` as the parity oracle, bit-identical by the same
contract as ``compact_backend='host'``. Un-shrink growth also REWARMS
the row cache (``rowcache.regrow_cache``) instead of invalidating it:
every tagged slot is recomputed over the grown buffer with the exact
in-loop compute islands, so tags, recency and counters survive the one
buffer rebuild that used to reset them.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import os
import time
import warnings
from typing import NamedTuple, Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from repro.core import dataplane, heuristics, mirror, rowcache, smo
from repro.data import sparse as spfmt
from repro.launch import chaos


@dataclasses.dataclass
class FitStats:
    iterations: int = 0
    n_sv: int = 0
    n_bound_sv: int = 0
    reconstructions: int = 0
    shrink_events: int = 0
    compactions: int = 0
    min_active: int = 0
    dispatches: int = 0          # fused-epoch runner launches; each reads
                                 # back ONE EpochSummary and nothing else,
                                 # so iterations / dispatches is the
                                 # amortization the fusion buys
    dispatch_times: list = dataclasses.field(default_factory=list)
                                 # wall seconds per dispatch (len ==
                                 # dispatches) — what BENCH_epoch.json plots
    train_time: float = 0.0
    recon_time: float = 0.0
    compact_time: float = 0.0    # wall time in physical compaction (either
                                 # backend) — what BENCH_compact.json plots
    total_time: float = 0.0
    converged: bool = False
    stalled: bool = False
    final_gap: float = 0.0
    buffer_sizes: list = dataclasses.field(default_factory=list)
    buffer_K: list = dataclasses.field(default_factory=list)
    # per-buffer ELL lane budget (adaptive K trajectory); empty for dense
    shard_K: list = dataclasses.field(default_factory=list)
    # per-buffer tuple of lane-rounded K per shard (host-side raggedness;
    # the device array is padded to max(shard_K) — XLA collectives need
    # uniform shapes, unlike the paper's per-rank MPI buffers)
    flops_est: float = 0.0       # model FLOPs of the gamma-update hot loop;
                                 # selection-aware (wss2 bills two single-row
                                 # passes + the selection sweep) and cache-
                                 # aware (hits skip the kernel-row pass and
                                 # are billed only the O(M) FMA epilogue)
    flops_production: float = 0.0   # kernel-row production component of
                                 # flops_est. Multi-problem rule: a row is
                                 # billed ONCE per physical production — a
                                 # cross-problem cache hit produces nothing
                                 # and is billed nothing here.
    flops_epilogue: float = 0.0  # O(M) FMA/selection epilogue component of
                                 # flops_est, billed per problem-iteration:
                                 # a shared row still feeds K distinct gamma
                                 # updates, so epilogue scales with sum_k
                                 # iters_k while production does not.
    cache_hits: int = 0          # kernel rows served from the LRU row cache
                                 # (aggregate over problems in a batched
                                 # multi fit; cross-problem reuse counts)
    cache_misses: int = 0        # kernel rows (re)computed by the provider
    cache_hit_rate: float = 0.0  # hits / (hits + misses); 0 when cache off
    n_problems: int = 1          # problems sharing this fit (K of a batched
                                 # core.multi fit; 1 for ordinary fits)
    per_problem: list = dataclasses.field(default_factory=list)
                                 # one record per problem lane of a multi
                                 # fit: iterations/converged/stalled/
                                 # shrink_events/reconstructions/n_sv/...
    joint_iters: int = 0         # batched while-loop body executions (the
                                 # stacked row GEMM runs once per joint
                                 # iteration regardless of how many problem
                                 # lanes are still live)
    mirror: str = ""             # resolved full-set mirror mode for this fit:
                                 # 'device' (jitted Alg. 6 + device un-shrink)
                                 # or 'host' (streaming paths / fallback)
    straggle_events: int = 0     # dispatches the StragglerWatchdog flagged
                                 # (each forced a checkpoint and halved the
                                 # fused segment budget)
    ckpt_retries: int = 0        # transient-I/O retries spent on checkpoint
                                 # writes (bounded by cfg.ckpt_retries)
    resumed_from: int = -1       # checkpoint step this fit restored from;
                                 # -1 = fresh start. A resume that fell
                                 # back past torn/corrupt saves reports the
                                 # step it actually loaded.


class CompactShardings(NamedTuple):
    """Output-sharding pins for the jitted compaction step (parallel mesh).
    ``None`` (single host) leaves placement to XLA."""
    rows: jax.sharding.Sharding        # (M, d) / (M, K) buffer arrays
    vec: jax.sharding.Sharding         # (M,) buffer arrays
    cache_vals: jax.sharding.Sharding  # (S, M) cache value table
    rep: jax.sharding.Sharding         # replicated scalars / (n,) masters


@functools.partial(jax.jit, static_argnames=("C",))
def _betas_step(gamma, alpha, y, *, C):
    """Eq. 8 bounds over all samples in ONE jitted reduction. ``C`` is
    static (a python float) so the ``C * _BND`` thresholds constant-fold
    f64 -> f32 exactly like the closure constants of ``smo.select_pair``
    — same index sets. Empty sets fall out of the inf/-inf sentinels with
    the same semantics as the old host early-outs."""
    pos = y > 0
    at0 = alpha <= C * smo._BND
    atc = alpha >= C * (1.0 - smo._BND)
    i0 = ~at0 & ~atc
    in_up = i0 | (pos & at0) | (~pos & atc)
    in_low = i0 | (pos & atc) | (~pos & at0)
    b_up = jnp.min(jnp.where(in_up, gamma, jnp.inf))
    b_low = jnp.max(jnp.where(in_low, gamma, -jnp.inf))
    return jnp.stack([b_up, b_low])


def betas(gamma, alpha, y, C: float):
    """Eq. 8 over all samples (reconstruction points and the solvers'
    finalize). Accepts host or device arrays; either way the reduction
    runs on device and the host syncs a single (2,) result — both bounds
    in one round-trip (the old per-bound reads cost two syncs when handed
    lazy device values, and an (n,) gamma readback besides)."""
    out = np.asarray(_betas_step(jnp.asarray(gamma), jnp.asarray(alpha),
                                 jnp.asarray(y), C=float(C)))
    return float(out[0]), float(out[1])


def _scatter_full(alpha_d, gamma_d, alpha_buf, gamma_buf, gids):
    """Scatter a buffer's alpha/gamma into the (n,) device masters (global
    ids key the scatter; padding rows gid=-1 are dropped via an
    out-of-bounds index). The ONE definition of the master-writeback rule —
    shared by the checkpoint/epoch writeback and the compaction step."""
    n = alpha_d.shape[0]
    safe = jnp.where(gids >= 0, gids, n)
    return (alpha_d.at[safe].set(alpha_buf, mode="drop"),
            gamma_d.at[safe].set(gamma_buf, mode="drop"))


@functools.partial(jax.jit, donate_argnames=("alpha_d", "gamma_d"))
def _writeback_step(alpha_d, gamma_d, alpha_buf, gamma_buf, gids):
    return _scatter_full(alpha_d, gamma_d, alpha_buf, gamma_buf, gids)


def _constrain(out, sh: CompactShardings):
    """Pin the compaction step's outputs to the solver's mesh layout so the
    next chunk's shard_map sees its expected shardings (correct either way;
    this avoids a reshard on entry)."""
    wsc = lax.with_sharding_constraint
    data, yb, state, cache, alpha_d, gamma_d = out
    if isinstance(data, dataplane.ELLData):
        data = dataplane.ELLData(wsc(data.vals, sh.rows),
                                 wsc(data.cols, sh.rows),
                                 wsc(data.sq_norms, sh.vec),
                                 data.n_features, wsc(data.gids, sh.vec))
    else:
        data = dataplane.DenseData(wsc(data.X, sh.rows),
                                   wsc(data.sq_norms, sh.vec),
                                   wsc(data.gids, sh.vec))
    vec = lambda a: wsc(a, sh.vec)
    rep = lambda a: wsc(a, sh.rep)
    state = state._replace(
        alpha=vec(state.alpha), gamma=vec(state.gamma),
        active=vec(state.active), beta_up=rep(state.beta_up),
        beta_low=rep(state.beta_low), i_up=rep(state.i_up),
        i_low=rep(state.i_low), step=rep(state.step),
        next_shrink=rep(state.next_shrink), n_shrinks=rep(state.n_shrinks),
        converged=rep(state.converged), stalled=rep(state.stalled))
    if cache is not None:
        cache = cache._replace(
            tags=rep(cache.tags), vals=wsc(cache.vals, sh.cache_vals),
            stamp=rep(cache.stamp), seg=rep(cache.seg), tick=rep(cache.tick),
            hits=rep(cache.hits), misses=rep(cache.misses))
    return (data, wsc(yb, sh.vec), state, cache, rep(alpha_d), rep(gamma_d))


@functools.partial(
    jax.jit, static_argnames=("p", "m_per", "K_new", "shards"),
    donate_argnames=("data", "yb", "state", "cache", "alpha_d", "gamma_d"))
def _compact_step(data, yb, state, cache, alpha_d, gamma_d, n_active,
                  interval, *, p, m_per, K_new, shards):
    """One device-side physical compaction (see module docstring).

    Everything the host rebuild used to do in numpy happens here in one
    XLA program: master writeback for the outgoing buffer, the balanced
    contiguous gather plan, the row/vector/cache gathers, and the fresh
    chunk state. ``p``/``m_per``/``K_new`` are static (power-of-two
    bucketed by the driver, so the executable cache stays O(log^2));
    ``n_active``/``interval`` ride as traced scalars so varying active
    counts do not retrace.
    """
    alpha_d, gamma_d = _scatter_full(alpha_d, gamma_d, state.alpha,
                                     state.gamma, data.gids)

    src, valid = dataplane.compact_plan(state.active, n_active, p, m_per)
    data2 = dataplane.gather_rows(data, src, valid, K_new)
    yb2 = jnp.where(valid, yb[src], 1.0)        # padding: y=+1, alpha=0 -> I1
    alpha2 = jnp.where(valid, state.alpha[src], 0.0)
    gamma2 = jnp.where(valid, state.gamma[src], jnp.float32(jnp.inf))
    state2 = smo.init_state(alpha2, gamma2, valid)._replace(
        step=state.step,
        next_shrink=state.step + jnp.maximum(
            jnp.int32(1), jnp.minimum(interval, n_active.astype(jnp.int32))),
        n_shrinks=state.n_shrinks)
    cache2 = rowcache.remap_cache_device(cache, src, valid)
    out = (data2, yb2, state2, cache2, alpha_d, gamma_d)
    if shards is not None:
        out = _constrain(out, shards)
    return out


class EpochDriver:
    """The Alg. 5 state machine around a solver's hook surface.

    One instance drives one ``fit``. The solver provides device placement
    (``_put``/``_put_full``/``_put_cache_vals``), runner construction
    (``_runner``), shard count (``_nshards``), cache sizing (``_new_cache``
    / ``_cache_slots``), Alg. 6 (``_reconstruct``) and — for the parallel
    mesh — compaction output shardings (``_compact_shardings``); the driver
    owns everything else. Mutable run state lives on the instance
    (``data``/``yb``/``state``/``cache``/device masters) so the compaction
    benchmark can reuse the exact fit-path methods.
    """

    def __init__(self, solver):
        self.s = solver
        self.cfg = solver.cfg
        self.h = solver.h
        self.mirror = None                      # device-resident full-set
                                                # mirror (set by fit when the
                                                # 'device' mode resolves)
        self.idx: Optional[np.ndarray] = None   # host mirror of data.gids;
                                                # None = stale (device compact
                                                # since last materialization)
        self._saves = 0                         # checkpoint-save boundary
                                                # counter (chaos hook key)

    # -- buffer plumbing ---------------------------------------------------
    def _make_buffer(self, y, alpha, gamma, idx):
        """Gather rows ``idx`` from the host store into a padded buffer of
        p balanced shards.

        Returns (data, y_buf, fresh state, idx_buf) where ``data`` is the
        device-side DenseData/ELLData buffer and idx_buf maps buffer row ->
        global sample index (-1 on padding rows). Active rows are
        distributed contiguously and evenly across shards — the paper's
        "load balancing ... requires contiguous data movement of samples"
        (Sec. 3.1.2). Row identity (``gids``) always travels with the
        buffer: it keys the row cache *and* the master writeback scatter of
        device-side compaction.

        ELL-family stores get an *adaptive* lane budget: K is recomputed
        from exactly the surviving rows (``store.buffer_K``) and bucketed
        to a power-of-two number of lanes (bounds jit retraces — K is a
        trace dimension of every chunk runner). Each shard's own
        lane-rounded K is recorded (``self._last_shard_K`` ->
        ``FitStats.shard_K``); the physical device array is padded to the
        bucketed max because XLA collectives require uniform shapes across
        shards, unlike the paper's per-rank MPI buffers which are truly
        ragged.
        """
        cfg, sv = self.cfg, self.s
        store = sv._store
        p = sv._nshards()
        m_per, K_buf = self._buffer_geometry(idx, p)
        m = m_per * p
        buf = store.alloc(m, K_buf)
        yb = np.ones((m,), np.float32)          # padding: y=+1, alpha=0 -> I1
        ab = np.zeros((m,), np.float32)
        gb = np.full((m,), np.inf, np.float32)  # padding gamma never selected
        sqb = np.zeros((m,), np.float32)
        valid = np.zeros((m,), bool)
        idx_buf = np.full((m,), -1, np.int64)
        for sl, sub in dataplane.deal(idx, p, m_per):
            store.fill(buf, sl, sub)
            yb[sl] = y[sub]
            ab[sl] = alpha[sub]
            gb[sl] = gamma[sub]
            # squared norms GATHER from the one store-level array (never
            # re-summed per buffer) so host fills, device compactions, the
            # mirror, and reconstruction SV blocks all share the same bits
            sqb[sl] = store.sq_rows(sub)
            valid[sl] = True
            idx_buf[sl] = sub
        data = store.to_device(buf, sv._put, gids=idx_buf, sq=sqb)
        state = smo.init_state(sv._put(ab), sv._put(gb), sv._put(valid))
        return data, sv._put(yb), state, idx_buf

    def _buffer_geometry(self, idx: np.ndarray, p: int):
        """Shared buffer-shape rule: per-shard slots (pow2 bucketed) and,
        for ELL stores, the adaptive lane budget of exactly ``idx`` —
        records per-shard K (``FitStats.shard_K``) as a side effect. The
        mirror build path computes the same geometry host-side, so both
        build backends agree on shapes without touching row data."""
        cfg, store = self.cfg, self.s._store
        m_per = mirror.full_m_per(idx.size, p, cfg.min_buffer)
        K_buf = None
        if store.fmt == "ell":
            K_buf = (spfmt.bucket_lanes(store.buffer_K(idx), cfg.ell_lane,
                                        cap=store.K)
                     if cfg.ell_adaptive else store.K)
            self._last_shard_K = tuple(
                store.buffer_K(sub)
                for _, sub in dataplane.deal(idx, p, m_per))
        else:
            self._last_shard_K = ()
        return m_per, K_buf

    def _mirror_build(self, rows: np.ndarray):
        """Device-side buffer build from the full-set mirror: the initial /
        resume-subset build and the un-shrink growth step. Same geometry,
        same balanced layout, same bits as :meth:`_make_buffer` — but rows,
        gids and sq_norms are gathered on device from the mirror and
        alpha/gamma from the (n,) masters, so no row data crosses the host
        link. Only the (m_mirror,) keep mask and the scalar count go up."""
        sv, mir = self.s, self.mirror
        p = mir.p
        m_per, K_new = self._buffer_geometry(rows, p)
        keep = np.zeros((mir.idx.size,), bool)
        keep[mir.pos_of[rows]] = True
        data, yb, state = mirror.grow_step(
            mir.data, mir.y, self.alpha_d, self.gamma_d, sv._put(keep),
            jnp.int32(rows.size), p=p, m_per=m_per, K_new=K_new,
            shards=sv._compact_shardings())
        idx_buf, _ = dataplane.full_layout(rows, p, m_per)
        return data, yb, state, idx_buf

    def _build_buffer(self, rows: np.ndarray):
        """Dispatch a buffer build for global rows ``rows``: device gather
        from the mirror when one is resident, host store fill otherwise.
        In host mode the masters are refreshed afterwards so both modes
        leave (buffer, masters) in the same (bitwise) state."""
        if self.mirror is not None:
            return self._mirror_build(rows)
        out = self._make_buffer(self.y, self.alpha, self.gamma, rows)
        self._refresh_masters()
        return out

    def _host_idx(self) -> np.ndarray:
        """Buffer position -> global sample id, materialized from the
        device ``gids`` lazily — device compaction leaves the host mirror
        stale rather than reading anything back."""
        if self.idx is None:
            self.idx = np.asarray(self.data.gids).astype(np.int64)
        return self.idx

    def _note_buffer(self):
        """Record buffer geometry: size always; K/shard-K on ELL buffers."""
        self.stats.buffer_sizes.append(self.data.m)
        if isinstance(self.data, dataplane.ELLData):
            self.stats.buffer_K.append(self.data.K)
            self.stats.shard_K.append(self._last_shard_K)

    # -- writeback ---------------------------------------------------------
    def _writeback_masters(self):
        """Scatter the current buffer's alpha/gamma into the (n,) device
        masters. Rows dropped at earlier compactions keep the drop-time
        values the compaction step scattered — same bits the host-backend
        rebuild would have written back then. No host traffic."""
        self.alpha_d, self.gamma_d = _writeback_step(
            self.alpha_d, self.gamma_d, self.state.alpha, self.state.gamma,
            self.data.gids)

    def _writeback(self):
        """Master writeback + full host sync of alpha/gamma (checkpoints,
        host-mode epoch boundaries, and the host compaction oracle)."""
        self._writeback_masters()
        # np.array (not asarray): jax arrays surface as read-only views and
        # reconstruction writes gamma[stale] in place
        self.alpha = np.array(self.alpha_d)
        self.gamma = np.array(self.gamma_d)

    def _refresh_masters(self):
        self.alpha_d = self.s._put_full(self.alpha)
        self.gamma_d = self.s._put_full(self.gamma)

    # -- gradient reconstruction (Alg. 6) ---------------------------------
    def _reconstruct_step(self, stale: np.ndarray):
        """Reconstruct gamma for the global rows ``stale``. Mirror mode:
        one jitted device program accumulating into the donated (n,) gamma
        master — only index vectors go up, NOTHING comes back (the Eq. 9
        check reads the masters through ``betas``, a (2,) sync; full host
        gamma is materialized lazily at checkpoints/fit exit). Host mode:
        the streaming oracle writes host gamma in place; the master is
        refreshed by the next buffer build. Both modes leave identical
        gamma bits."""
        sv, y = self.s, self.y
        if stale.size == 0:
            return
        sv_rows = np.flatnonzero(self.alpha > 0.0)
        if self.mirror is not None:
            if sv_rows.size:
                self.gamma_d = sv._reconstruct_mirror(
                    self.mirror, self.alpha_d, self.gamma_d, sv_rows, stale)
            else:
                # no support vectors: Alg. 6 degenerates to gamma = -y.
                # Scatter on device (same bits as the host early-out; a
                # rare path, so eager ops rather than a per-shape jit)
                st = jnp.asarray(stale)
                self.gamma_d = self.gamma_d.at[st].set(-self.y_d[st])
            return
        if sv_rows.size == 0:
            # no support vectors: Alg. 6 degenerates to gamma = -y (same
            # bits as the oracle's early-out)
            self.gamma[stale] = (-y[stale]).astype(np.float32)
        else:
            self.gamma[stale] = sv._reconstruct(y, self.alpha, stale)

    # -- physical compaction ----------------------------------------------
    def _compact(self, n_active: int, p: int, m_per: int, shard_ext=None):
        """One physical compaction — device backend by default, host
        backend (store rebuild) as the parity oracle. ``shard_ext`` is the
        (p,) per-shard surviving ELL extents when the caller already has
        them (the fused-epoch summary computes them in-dispatch via
        ``dataplane.ell_shard_extents_dyn``); ``None`` falls back to the
        standalone extent scan — same values by the dyn/static parity
        contract."""
        cfg, sv = self.cfg, self.s
        t0 = time.perf_counter()
        ell = isinstance(self.data, dataplane.ELLData)
        if cfg.compact_backend == "device":
            K_new = None
            if ell:
                # per-shard surviving extents: their max fixes the lane
                # bucket (host-side bucket_lanes, exactly like the host
                # rebuild buckets store.buffer_K) and the per-shard values
                # feed FitStats.shard_K. Normally these rode the epoch
                # summary — the dedicated scan dispatch is the fallback
                # for callers outside the fused loop
                lane = sv._store.lane
                if shard_ext is None:
                    shard_ext = np.asarray(dataplane.ell_shard_extents(
                        self.data.vals, self.state.active,
                        jnp.int32(n_active), p=p, m_per=m_per))
                self._last_shard_K = tuple(
                    spfmt.round_lanes(int(e), lane) for e in shard_ext)
                K_new = (spfmt.bucket_lanes(int(shard_ext.max()), lane,
                                            cap=sv._store.K)
                         if cfg.ell_adaptive else self.data.K)
            with warnings.catch_warnings():
                # shrinking means the outputs are smaller than the donated
                # inputs, so XLA cannot alias them — donation still frees
                # the old buffer at entry, which is the point
                warnings.filterwarnings(
                    "ignore", message="Some donated buffers were not usable")
                (self.data, self.yb, self.state, self.cache, self.alpha_d,
                 self.gamma_d) = _compact_step(
                    self.data, self.yb, self.state, self.cache, self.alpha_d,
                    self.gamma_d, jnp.int32(n_active),
                    jnp.int32(self._interval), p=p, m_per=m_per, K_new=K_new,
                    shards=sv._compact_shardings())
            self.idx = None
        else:
            self._writeback()
            idx = self._host_idx()
            keep = idx[(idx >= 0) & np.asarray(self.state.active)]
            idx_old, step, nshr = idx, self.state.step, self.state.n_shrinks
            self.data, self.yb, state2, self.idx = self._make_buffer(
                self.y, self.alpha, self.gamma, keep)
            # survivors keep their global ids -> cached rows are re-gathered
            # into the compacted geometry, not dropped
            self.cache = rowcache.remap_cache(self.cache, idx_old, self.idx,
                                              sv._put_cache_vals)
            self.state = state2._replace(
                step=step,
                next_shrink=step + max(1, min(self._interval, keep.size)),
                n_shrinks=nshr)
        jax.block_until_ready(self.state.alpha)
        self.stats.compactions += 1
        self.stats.compact_time += time.perf_counter() - t0
        self._note_buffer()

    # -- fault tolerance ---------------------------------------------------
    # Recovery path (save boundary == dispatch boundary == restore
    # boundary):
    #
    #   dispatch #i ──▶ EpochSummary ──▶ [cadence or straggle?]
    #        ▲                               │ yes: _writeback() masters,
    #        │                               ▼      atomic step_{N} save
    #        │                     checkpoint_dir/step_{N}/ (host (n,)
    #        │                     alpha/gamma/active/in_buffer + meta;
    #        │                     NO mesh or layout state — buffers are
    #        │                     rebuilt, not saved)
    #        │   crash / rescale ──▶ restart with resume=True
    #        │                               │ newest COMPLETE step
    #        │                               ▼ (torn/corrupt skipped)
    #        └── _build_buffer(in_buffer rows): re-deal the balanced
    #            layout for the CURRENT device count p', restore per-row
    #            active flags, recompute ELL lane budgets, rebuild the
    #            row cache empty, re-enter the fused loop mid-schedule at
    #            the saved step.
    #
    # Mesh portability falls out of what is (not) saved: checkpoints hold
    # only global (n,) masters + the active and buffer-membership masks,
    # and every buffer build is already a pure function of (row set, p) —
    # dataplane's balanced contiguous layout, mirror.full_m_per's one
    # rounding rule, and the adaptive ELL lane budget are all recomputed
    # for the new p. A fit saved under N devices therefore restores onto
    # M devices by the SAME code path as a plain restart. Restoring the
    # saved MEMBERSHIP (not just the active set) means a same-mesh resume
    # rebuilds the saved run's exact buffer geometry — same executable,
    # bitwise continuation; a different device count changes shard shapes
    # and so drifts by ulps across executables (iterations and objective
    # still match — the PR-8 cross-executable contract).
    def _ckpt_meta(self, n: int) -> dict:
        """Config fingerprint saved with (and validated against) every
        checkpoint. Deliberately EXCLUDES the device count and any buffer
        geometry — those are free to change across restore — and eps/
        iteration budgets, which a resume may legitimately retune."""
        cfg = self.cfg
        return {"n": int(n), "format": cfg.format, "C": float(cfg.C),
                "sigma2": float(cfg.sigma2), "selection": cfg.selection,
                "heuristic": self.h.name}

    def _validate_meta(self, meta: dict, n: int, d: str):
        for k, v in self._ckpt_meta(n).items():
            if k in meta and meta[k] != v:
                raise ValueError(
                    f"checkpoint {d} was saved with {k}={meta[k]!r} but "
                    f"this fit has {k}={v!r} — refusing to resume a "
                    "different problem/configuration")

    def _save_ckpt(self, act_full: np.ndarray, in_buf: np.ndarray,
                   meta: dict):
        from repro.ckpt import checkpoint as ck
        chaos.on_save(self._saves)
        self._saves += 1
        meta = dict(meta, **self._ckpt_meta(self.alpha.size))
        d = os.path.join(self.cfg.checkpoint_dir, f"step_{meta['step']}")
        _, retries = ck.with_retries(
            lambda: ck.save(
                d, meta["step"],
                {"svm": {"alpha": self.alpha, "gamma": self.gamma,
                         "active": act_full.astype(np.int8),
                         "in_buffer": in_buf.astype(np.int8)}},
                extra=meta),
            attempts=max(1, self.cfg.ckpt_retries),
            what=f"checkpoint save {d}")
        self.stats.ckpt_retries += retries

    def _load_ckpt(self, n: int):
        """Restore the newest COMPLETE checkpoint, walking past torn or
        corrupt step dirs (a config/shape mismatch raises instead — that
        is a caller error, not a disk fault)."""
        from repro.ckpt import checkpoint as ck
        base = self.cfg.checkpoint_dir
        like = {"alpha": np.zeros(n, np.float32),
                "gamma": np.zeros(n, np.float32),
                "active": np.zeros(n, np.int8),
                "in_buffer": np.zeros(n, np.int8)}
        for step in reversed(ck.complete_steps(base)):
            d = os.path.join(base, f"step_{step}")
            try:
                man = ck.load_manifest(d)
            except (OSError, ValueError):
                continue
            meta = man.get("extra", {})
            self._validate_meta(meta, n, d)
            try:
                g = ck.restore(d, "svm", like)
            except (IOError, KeyError) as e:
                warnings.warn(f"skipping corrupt checkpoint {d}: {e}")
                continue
            self.stats.resumed_from = int(step)
            return ({k: np.array(v) for k, v in g.items()}, meta)
        return None

    def _checkpoint_now(self, n: int, step_host: int, nshr: int,
                        recon_count: int, shrink_on: bool):
        """Sync masters to host and write one atomic step dir at the
        CURRENT dispatch boundary — the cadence path and the watchdog's
        forced save share this exactly.

        Besides the masters + active mask, the save records buffer
        MEMBERSHIP (`in_buffer`: which global rows the buffer currently
        holds — active plus shrunk-but-not-yet-compacted). Membership is
        an (n,) mask, not a layout: restore re-deals exactly this row
        set for the *current* device count, which reproduces the saved
        run's buffer geometry bit-for-bit on the same mesh (same rows ->
        same ``full_m_per`` -> same executable) while remaining
        mesh-portable (a different p just deals the same set p ways)."""
        self._writeback()
        idx = self._host_idx()
        valid = idx >= 0
        act_full = np.zeros((n,), bool)
        act_full[idx[valid & np.asarray(self.state.active)]] = True
        in_buf = np.zeros((n,), bool)
        in_buf[idx[valid]] = True
        self._save_ckpt(act_full, in_buf, {
            "step": step_host,
            "shrink_events": nshr,
            "recon_count": recon_count,
            "shrink_on": shrink_on,
            # the shrink schedule is anchored at the last shrink/compact
            # event, not at this boundary — save the anchor so a resumed
            # run shrinks at the same iterations the killed run would have
            "next_shrink": int(jax.device_get(self.state.next_shrink))})

    # -- main --------------------------------------------------------------
    def fit(self, X, y: np.ndarray):
        """Run Alg. 5 on ``(X, y)``; returns ``(alpha, gamma, y, stats)``
        for the solver's finalize. ``X`` is a dense (n, d) matrix, or —
        with ``format='ell'`` — CSR input, which streams CSR->ELL buffers
        and never allocates dense X on host."""
        cfg, h, sv = self.cfg, self.h, self.s
        if cfg.compact_backend not in ("device", "host"):
            raise ValueError(
                f"unknown compact_backend {cfg.compact_backend!r} "
                "(want 'device' or 'host')")
        if cfg.row_cache_policy not in rowcache.POLICIES:
            raise ValueError(
                f"unknown row_cache_policy {cfg.row_cache_policy!r}; "
                f"known: {rowcache.POLICIES}")
        t0 = time.perf_counter()
        if spfmt.is_csr_like(X):
            X = spfmt.as_csr(X)      # normalizes scipy-like/tuple forms
        else:
            X = np.ascontiguousarray(X, np.float32)
        y = np.ascontiguousarray(y, np.float32)
        n, d = (int(s) for s in X.shape)
        assert set(np.unique(y)) <= {-1.0, 1.0}, "labels must be +-1"
        sv._store = dataplane.make_store(X, cfg.format, cfg.ell_K,
                                         cfg.ell_lane)
        del X                                  # train from the store only

        self.y = y
        self.alpha = np.zeros((n,), np.float32)
        self.gamma = (-y).astype(np.float32)
        self.stats = stats = FitStats(min_active=n)

        interval = self._interval = h.interval(n)
        tol20 = jnp.float32(cfg.recon_eps_factor * cfg.eps)
        tol2 = jnp.float32(2.0 * cfg.eps)

        shrink_on = h.policy != "none"
        recon_count = 0
        t_train = 0.0
        t_recon = 0.0
        stalled = False
        step0, nshr0, act_full0, ns0 = 0, 0, None, None
        if cfg.resume and cfg.checkpoint_dir:
            got = self._load_ckpt(n)
            if got is not None:
                g, meta = got
                self.alpha, self.gamma = g["alpha"], g["gamma"]
                act_full0 = g["active"].astype(bool)
                in_buf0 = g["in_buffer"].astype(bool)
                step0 = int(meta["step"])
                nshr0 = int(meta.get("shrink_events", 0))
                recon_count = int(meta.get("recon_count", 0))
                shrink_on = bool(meta.get("shrink_on", shrink_on))
                ns0 = meta.get("next_shrink")
                stats.reconstructions = recon_count

        # Build the runner only after a possible restore: a Single-policy
        # checkpoint taken post-reconstruction carries shrink_on=False, and
        # a runner pre-built with interval > 0 would silently re-enable
        # shrinking on resume (stale gammas, broken Eq. 9 bookkeeping).
        run_interval = interval if shrink_on else 0
        runner = sv._runner(cfg, run_interval)

        # Resolve + build the device-resident full-set mirror (after the
        # restore: a shrink-free run never reconstructs or grows, so the
        # mirror would be dead weight). Masters are refreshed FIRST — the
        # mirror build path gathers alpha/gamma from them.
        mode, mir_m_per, mir_K, _ = mirror.resolve(cfg, sv._store,
                                                   sv._nshards(), shrink_on)
        stats.mirror = mode
        self.mirror = (mirror.build(sv._store, y, sv._put, sv._nshards(),
                                    mir_m_per, mir_K)
                       if mode == "device" else None)
        if self.mirror is not None:
            self._refresh_masters()     # the mirror build path gathers
                                        # alpha/gamma from the masters;
                                        # _build_buffer refreshes them
                                        # itself on the host path
            self.y_d = sv._put_full(y)  # device-side Eq. 9 (betas) reads
                                        # the masters; it needs y in the
                                        # same global order

        if act_full0 is not None and shrink_on:
            # rebuild the SAVED buffer membership (active plus shrunk-but-
            # not-yet-compacted rows), not just the active set: on the same
            # mesh the re-deal then reproduces the saved run's buffer
            # geometry exactly (same rows -> same full_m_per -> same
            # executable), and on a different mesh it is the same set dealt
            # p' ways
            rows = np.flatnonzero(in_buf0)
        else:
            rows = np.arange(n)
        self.data, self.yb, self.state, self.idx = self._build_buffer(rows)
        if act_full0 is not None and shrink_on:
            # restore each buffer row's logical active flag from the saved
            # mask — a fresh build marks every valid row active, which
            # would resurrect rows the saved run had already shrunk
            ib = self.idx
            actb = np.where(ib >= 0, act_full0[np.maximum(ib, 0)], False)
            self.state = self.state._replace(active=sv._put(actb))
        self._note_buffer()
        self.state = self.state._replace(step=jnp.int32(step0),
                                         n_shrinks=jnp.int32(nshr0))
        if run_interval > 0:
            # resume: restore the saved shrink-schedule anchor so shrink
            # events land at the same iterations as the uninterrupted run
            # (the schedule is anchored at the last shrink/compact event,
            # not at the checkpoint boundary); fresh start: first shrink
            # one interval in
            ns = step0 + run_interval if ns0 is None else int(ns0)
            self.state = self.state._replace(next_shrink=jnp.int32(ns))
        ckpt_count = 0
        # straggler watchdog (off unless watchdog_threshold > 0): watches
        # the per-dispatch wall times the stats already record; a flagged
        # dispatch forces a checkpoint at this boundary (the elastic
        # restart path can take over at zero lost work) and halves the
        # fused segment budget so the NEXT slow dispatch wastes less
        watchdog = None
        if cfg.watchdog_threshold > 0:
            from repro.launch.elastic import StragglerWatchdog
            watchdog = StragglerWatchdog(
                threshold=cfg.watchdog_threshold,
                window=cfg.watchdog_window, warmup=cfg.watchdog_warmup)
        # LRU/SLRU kernel-row cache (None when off). Never checkpointed:
        # cached rows are exact, so rebuilding it empty on resume is
        # trajectory-neutral. miss_seen tracks the cumulative miss counter
        # so each chunk's flops bill only the rows actually recomputed.
        self.cache = sv._new_cache(self.data.m)
        miss_seen = 0
        fuse = max(1, int(cfg.fuse_iters))
        p = sv._nshards()
        mper_lo = max(cfg.min_buffer // p, 8)   # full_m_per's clamp floor,
                                                # for the device predicate
        step_host = step0

        while True:
            tol = tol20 if (shrink_on and recon_count == 0) else tol2
            # ---- inner optimization at current tolerance ----------------
            # Each pass dispatches ONE fused epoch of up to k_eff segments
            # and syncs ONE EpochSummary; every decision below reads
            # summary fields — state/cache stay on device untouched.
            while True:
                if watchdog is not None:
                    watchdog.start_step()
                tc = time.perf_counter()
                # the chaos hook sits INSIDE the timed region: an injected
                # delay inflates this dispatch's wall time exactly like a
                # real straggler would, so the watchdog sees it; a kill
                # fires before the runner launches (boundary semantics)
                chaos.on_dispatch(stats.dispatches)
                step_before = step_host
                # clip the segment budget to the checkpoint cadence so a
                # fused run saves at exactly the oracle's iteration counts
                k_eff = (heuristics.fuse_budget(fuse, ckpt_count,
                                                cfg.checkpoint_every)
                         if cfg.checkpoint_dir else fuse)
                # host twin of the device compaction trigger: n_active <
                # ceil(ratio * m) is the integer-exact form of the float
                # compare the host loop used to do
                compact_lt = (math.ceil(cfg.compact_ratio * self.data.m)
                              if shrink_on else 0)
                self.state, self.cache, summ_d = runner(
                    self.data, self.yb, self.state, self.cache, tol,
                    jnp.int32(k_eff), jnp.int32(cfg.chunk_iters),
                    jnp.int32(cfg.max_iters), jnp.int32(compact_lt),
                    jnp.int32(mper_lo))
                summ = jax.device_get(summ_d)   # the one, fixed-size sync
                dt = time.perf_counter() - tc
                t_train += dt
                stats.dispatches += 1
                stats.dispatch_times.append(dt)
                step_host = int(summ.step)
                iters_done = step_host - step_before
                n_active = int(summ.n_active)
                stats.min_active = min(stats.min_active,
                                       int(summ.min_active))
                # hot-loop model FLOPs, selection- and cache-aware: each
                # iteration pays the O(M) epilogue (Eq. 6 FMA; wss2 adds
                # the second-order selection sweep), plus one kernel-row
                # pass per row actually computed — 2/iter without the
                # cache, the provider-miss count with it.
                if self.cache is not None:
                    misses_now = int(summ.cache_misses)
                    rows_new = misses_now - miss_seen
                    miss_seen = misses_now
                else:
                    rows_new = 2 * iters_done
                epilogue = 12.0 if cfg.selection == "wss2" else 4.0
                prod = (rows_new * self.data.flops_row_pass()
                        * float(self.data.m))
                epi = iters_done * epilogue * float(self.data.m)
                stats.flops_production += prod
                stats.flops_epilogue += epi
                stats.flops_est += prod + epi
                straggled = (watchdog is not None and watchdog.end_step())
                if straggled:
                    stats.straggle_events += 1
                    fuse = max(1, fuse // 2)
                if cfg.checkpoint_dir:
                    ckpt_count += int(summ.segs)
                    if ckpt_count % cfg.checkpoint_every == 0 or straggled:
                        self._checkpoint_now(n, step_host,
                                             int(summ.n_shrinks),
                                             recon_count, shrink_on)
                if bool(summ.converged) or bool(summ.stalled) \
                        or step_host >= cfg.max_iters:
                    break
                # physical compaction between chunks (DESIGN.md SS4) —
                # the runner evaluated the trigger on device and stopped
                # the epoch at the boundary; the host only buckets the new
                # geometry (and, on ELL, reuses the summary's extents)
                if bool(summ.need_compact):
                    m_per = mirror.full_m_per(n_active, p, cfg.min_buffer)
                    self._compact(n_active, p, m_per,
                                  shard_ext=np.asarray(summ.shard_ext))
            stalled = stalled or bool(summ.stalled)
            # n_shrinks is cumulative for the whole run (carried through
            # compactions/reconstructions, restored from checkpoints), so
            # assign — a += here grew quadratically with reconstructions
            # under the Multi policy.
            stats.shrink_events = int(summ.n_shrinks)
            if self.mirror is not None:
                # device mode: scatter the buffer into the masters and sync
                # host alpha only (reconstruction picks the SV set on
                # host). Host gamma stays stale — Eq. 9 below reads the
                # masters, and full gamma is materialized once at fit exit
                # (checkpoints sync it themselves via _writeback).
                self._writeback_masters()
                self.alpha = np.array(self.alpha_d)
            else:
                self._writeback()

            if not shrink_on or recon_count >= cfg.max_reconstructions \
                    or step_host >= cfg.max_iters:
                break

            # ---- gradient reconstruction + un-shrink (Alg. 5 l. 26-33) --
            tr = time.perf_counter()
            idx = self._host_idx()
            act = np.zeros((n,), bool)
            live = (idx >= 0) & np.asarray(self.state.active)
            act[idx[live]] = True
            stale = np.flatnonzero(~act)
            self._reconstruct_step(stale)
            t_recon += time.perf_counter() - tr
            recon_count += 1

            # optimality over ALL samples (Eq. 9) — one (2,) sync either
            # way; device mode reads the masters, never the (n,) gamma
            if self.mirror is not None:
                b_up, b_low = betas(self.gamma_d, self.alpha_d, self.y_d,
                                    cfg.C)
            else:
                b_up, b_low = betas(self.gamma, self.alpha, y, cfg.C)
            if b_up + 2.0 * cfg.eps >= b_low:
                self.state = self.state._replace(converged=jnp.bool_(True))
                break
            # un-shrink: rebuild the full buffer (device mirror gather or
            # host store rebuild); Single disables shrinking. The row
            # cache SURVIVES the growth under BOTH selections: every
            # tagged slot is rewarmed against the grown buffer with the
            # exact in-loop compute island — wss1 the fused two-row pass,
            # wss2 the duplicated-query rows2 single-row island
            # (kernel_fns.row_via_rows2, which resolved the GEMV context
            # instability that used to force wholesale invalidation here)
            # — so tags, recency and counters carry across (exact: a
            # later hit serves the bits an in-loop miss would have
            # computed; enforced by the cache exactness tests).
            step_save = step_host
            nshr = int(summ.n_shrinks)
            self.data, self.yb, self.state, self.idx = self._build_buffer(
                np.arange(n))
            if self.cache is not None:
                self.cache = sv._regrow_cache(
                    self.cache, self.data, cfg.selection != "wss2", n)
            self._note_buffer()
            if h.policy == "single":
                shrink_on = False
                runner = sv._runner(cfg, 0)
            else:
                runner = sv._runner(cfg, interval)
                self.state = self.state._replace(
                    next_shrink=jnp.int32(step_save + interval))
            self.state = self.state._replace(step=jnp.int32(step_save),
                                             n_shrinks=jnp.int32(nshr))

        # ---- account ----------------------------------------------------
        if self.mirror is not None:
            # the one full (n,) gamma materialization of a device-mode fit
            # (plus any checkpoints); alpha was synced at the epoch tail
            self.gamma = np.array(self.gamma_d)
        stats.iterations = step_host
        stats.reconstructions = recon_count
        stats.train_time = t_train
        stats.recon_time = t_recon
        stats.stalled = stalled
        if self.cache is not None:
            stats.cache_hits = int(self.cache.hits)
            stats.cache_misses = int(self.cache.misses)
            looked = stats.cache_hits + stats.cache_misses
            stats.cache_hit_rate = (stats.cache_hits / looked
                                    if looked else 0.0)
        stats.total_time = time.perf_counter() - t0
        return self.alpha, self.gamma, y, stats
