"""SMO driver — the paper's Algorithm 5 control flow.

Phases (faithful to Alg. 5):

  shrink stage    run jitted SMO chunks with in-loop shrinking until
                  beta_up + 20*eps >= beta_low on the active set; physically
                  compact the buffer between chunks when enough samples have
                  been shrunk (this is where the FLOP/byte reduction the
                  paper measures actually lands on TPU);
  reconstruct     Alg. 6 for every non-active sample, then un-shrink
                  (reset pi_q) and re-check optimality over ALL samples;
  re-optimize     Single: shrinking disabled, run to 2*eps.
                  Multi:  shrinking re-enabled (counter reset), run to 2*eps
                          on the active set, reconstruct again, repeat until
                          Eq. 9 holds over all samples.

The "Original" baseline (Alg. 3, no shrinking) is the same driver with the
shrink interval = 0 and no reconstruction, run straight to 2*eps.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import dataplane
from repro.core import heuristics as H
from repro.core import kernel_fns, reconstruct, rowcache, smo
from repro.data import sparse as spfmt


@dataclasses.dataclass
class SVMConfig:
    C: float = 1.0
    kernel: str = "rbf"
    sigma2: float = 1.0          # Gaussian width; K = exp(-||x-z||^2 / (2 sigma^2))
    eps: float = 1e-3            # user tolerance (Eq. 9 uses 2*eps)
    heuristic: "str | H.ShrinkHeuristic" = "original"
    selection: str = "wss1"      # 'wss2': second-order pair selection (the
                                 # paper's stated future work; fewer
                                 # iterations, 2 kernel-row passes/iter)
    format: str = "dense"        # sample storage: 'dense' | 'ell' (block-ELL
                                 # sparse, paper Sec. 2.2; wins memory when
                                 # density < d / 2K)
    ell_K: "int | None" = None   # ELL nonzero budget per row; default = max
                                 # row nnz rounded up to ``ell_lane``; explicit
                                 # values are rounded up to a lane multiple
    ell_lane: int = 128          # TPU lane multiple for the ELL K padding
    ell_adaptive: bool = True    # recompute K from the *surviving* rows at
                                 # every buffer build/compaction (bucketed to
                                 # power-of-two lanes); False pins K to the
                                 # store-wide ingest budget
    row_cache: bool = False      # device-resident LRU kernel-row cache in
                                 # front of the row provider; exact (cache
                                 # on/off trajectories are bit-identical)
    row_cache_slots: int = 64    # cache capacity (rows); bucketed to a power
                                 # of two so it is not a jit retrace dimension
    max_iters: int = 4_000_000
    chunk_iters: int = 256       # jitted while_loop segment length; smaller
                                 # chunks let physical compaction engage
                                 # sooner (-16% gamma-update FLOPs measured
                                 # on a9a; EXPERIMENTS.md section Perf/SVM-2)
    compact_ratio: float = 0.55  # compact buffer when active fraction < this
    min_buffer: int = 256
    recon_eps_factor: float = 20.0  # Alg. 5 line 7 first-reconstruction gate
    use_pallas: bool = False
    max_reconstructions: int = 64   # safety bound for Multi
    checkpoint_dir: "str | None" = None  # save SMO state between chunks
    checkpoint_every: int = 1       # in chunks
    resume: bool = False            # restore from checkpoint_dir if present

    @property
    def inv_2s2(self) -> float:
        return 1.0 / (2.0 * self.sigma2)


@dataclasses.dataclass
class FitStats:
    iterations: int = 0
    n_sv: int = 0
    n_bound_sv: int = 0
    reconstructions: int = 0
    shrink_events: int = 0
    compactions: int = 0
    min_active: int = 0
    train_time: float = 0.0
    recon_time: float = 0.0
    total_time: float = 0.0
    converged: bool = False
    stalled: bool = False
    final_gap: float = 0.0
    buffer_sizes: list = dataclasses.field(default_factory=list)
    buffer_K: list = dataclasses.field(default_factory=list)
    # per-buffer ELL lane budget (adaptive K trajectory); empty for dense
    shard_K: list = dataclasses.field(default_factory=list)
    # per-buffer tuple of lane-rounded K per shard (host-side raggedness;
    # the device array is padded to max(shard_K) — XLA collectives need
    # uniform shapes, unlike the paper's per-rank MPI buffers)
    flops_est: float = 0.0       # model FLOPs of the gamma-update hot loop;
                                 # selection-aware (wss2 bills two single-row
                                 # passes + the selection sweep) and cache-
                                 # aware (hits skip the kernel-row pass and
                                 # are billed only the O(M) FMA epilogue)
    cache_hits: int = 0          # kernel rows served from the LRU row cache
    cache_misses: int = 0        # kernel rows (re)computed by the provider
    cache_hit_rate: float = 0.0  # hits / (hits + misses); 0 when cache off


@dataclasses.dataclass
class SVMModel:
    config: SVMConfig
    sv_x: "np.ndarray | None"    # (n_sv, d); None when SVs are stored ELL
    sv_coef: np.ndarray          # (n_sv,)  alpha_i * y_i
    beta: float
    alpha: np.ndarray            # (N,) full multipliers (diagnostics)
    stats: FitStats
    sv_vals: "np.ndarray | None" = None   # (n_sv, K) ELL support vectors
    sv_cols: "np.ndarray | None" = None   # (n_sv, K)
    n_features: "int | None" = None       # d (set for ELL models)

    def _sv_kernel_fn(self):
        """jitted z_block -> K(z_block, SVs): the row provider's ``matrix``
        over an SV device buffer in its native storage format."""
        cfg = self.config
        if self.sv_vals is not None:
            vals = jnp.asarray(self.sv_vals)
            data = dataplane.ELLData(vals, jnp.asarray(self.sv_cols),
                                     jnp.sum(vals * vals, axis=-1),
                                     self.n_features)
            fmt = "ell"
        else:
            svx = jnp.asarray(self.sv_x)
            data = dataplane.DenseData(svx, jnp.sum(svx * svx, axis=-1))
            fmt = "dense"
        provider = kernel_fns.make_provider(cfg.kernel, fmt,
                                            inv_2s2=cfg.inv_2s2)
        return jax.jit(lambda z: provider.matrix(data, z))

    def _sv_dense(self) -> np.ndarray:
        """Support vectors as a dense (n_sv, d) block (query side of K)."""
        if self.sv_vals is None:
            return self.sv_x
        store = dataplane.ELLStore(self.sv_vals, self.sv_cols,
                                   self.n_features)
        return store.dense_rows(np.arange(self.sv_vals.shape[0]))

    def decision_function(self, Z: np.ndarray, block: int = 8192) -> np.ndarray:
        out = np.empty((Z.shape[0],), np.float32)
        coef = jnp.asarray(self.sv_coef)
        kf = self._sv_kernel_fn()
        f = jax.jit(lambda z: kf(z) @ coef - self.beta)
        for s in range(0, Z.shape[0], block):
            zb = Z[s: s + block]
            pad = block - zb.shape[0]
            if pad:
                zb = np.pad(zb, ((0, pad), (0, 0)))
            out[s: s + min(block, Z.shape[0] - s)] = np.asarray(
                f(jnp.asarray(zb)))[: Z.shape[0] - s]
        return out

    def predict(self, Z: np.ndarray) -> np.ndarray:
        return np.where(self.decision_function(Z) >= 0.0, 1.0, -1.0)

    def dual_objective(self) -> float:
        """L_D (Eq. 1) over the support set — used by tests/benchmarks."""
        K = np.asarray(self._sv_kernel_fn()(jnp.asarray(self._sv_dense())))
        a = np.abs(self.sv_coef)           # alpha (coef = alpha*y)
        return float(a.sum() - 0.5 * self.sv_coef @ K @ self.sv_coef)


def _bucket(n: int, lo: int) -> int:
    return min(max(lo, 1 << (int(n - 1)).bit_length()), 1 << 30) if n > 0 else lo


_RUNNER_CACHE: dict = {}


class SMOSolver:
    """Single-host SMO with adaptive shrinking. See ``repro.core.parallel``
    for the shard_map multi-device version."""

    def __init__(self, config: SVMConfig):
        self.cfg = config
        self.h = H.get(config.heuristic)

    # -- backend hooks (overridden by repro.core.parallel) --------------------
    def _cache_slots(self) -> int:
        """Row-cache capacity: 0 when disabled, else power-of-two bucketed
        so user-tuned values do not each get their own XLA executable."""
        if not self.cfg.row_cache:
            return 0
        return rowcache.bucket_slots(self.cfg.row_cache_slots)

    def _put_cache_vals(self, arr: np.ndarray):
        """Placement for the (slots, M) cache value table; the parallel
        subclass shards it over the mesh on the buffer axis."""
        return jnp.asarray(arr)

    def _new_cache(self, m: int):
        slots = self._cache_slots()
        if slots == 0:
            return None
        return rowcache.init_cache(slots, m, self._put_cache_vals)

    def _runner(self, cfg: SVMConfig, interval: int):
        key = (cfg.kernel, cfg.C, cfg.inv_2s2, interval, cfg.use_pallas,
               cfg.selection, cfg.format, self._cache_slots())
        if key not in _RUNNER_CACHE:
            _RUNNER_CACHE[key] = smo.make_chunk_runner(
                cfg.kernel, cfg.C, cfg.inv_2s2, interval, cfg.use_pallas,
                selection=cfg.selection, fmt=cfg.format,
                cache_slots=self._cache_slots())
        return _RUNNER_CACHE[key]

    def _reconstruct(self, y, alpha, stale):
        """Alg. 6 for global row indices ``stale``; host-blocked baseline.
        Consumes the data-plane store, so ELL storage streams densified
        blocks instead of materializing a dense X."""
        return reconstruct.reconstruct_gamma_store(
            self.cfg.kernel, self._store, y, alpha, stale, self.cfg.inv_2s2)

    # -- buffer plumbing -----------------------------------------------------
    def _nshards(self) -> int:
        return 1

    def _put(self, arr: np.ndarray):
        """Device placement hook; the parallel subclass shards over the mesh."""
        return jnp.asarray(arr)

    def _make_buffer(self, y, alpha, gamma, idx):
        """Gather rows ``idx`` from the host store into a padded buffer of p
        balanced shards.

        Returns (data, y_buf, fresh state, idx_buf) where ``data`` is the
        device-side DenseData/ELLData buffer and idx_buf maps buffer row ->
        global sample index (-1 on padding rows). Active rows are distributed
        contiguously and evenly across shards — the paper's "load balancing
        ... requires contiguous data movement of samples" (Sec. 3.1.2).

        ELL-family stores get an *adaptive* lane budget: K is recomputed
        from exactly the surviving rows (``store.buffer_K``) and bucketed to
        a power-of-two number of lanes (bounds jit retraces — K is a trace
        dimension of every chunk runner). Each shard's own lane-rounded K is
        also recorded (``self._last_shard_K`` -> ``FitStats.shard_K``); the
        physical device array is padded to the bucketed max because XLA
        collectives require uniform shapes across shards, unlike the
        paper's per-rank MPI buffers which are truly ragged.
        """
        p = self._nshards()
        m_per = _bucket(-(-idx.size // p), max(self.cfg.min_buffer // p, 8))
        m = m_per * p
        ell = self._store.fmt == "ell"
        K_buf = None
        if ell:
            K_buf = (spfmt.bucket_lanes(self._store.buffer_K(idx),
                                        self.cfg.ell_lane, cap=self._store.K)
                     if self.cfg.ell_adaptive else self._store.K)
        buf = self._store.alloc(m, K_buf)
        yb = np.ones((m,), np.float32)          # padding: y=+1, alpha=0 -> I1
        ab = np.zeros((m,), np.float32)
        gb = np.full((m,), np.inf, np.float32)  # padding gamma never selected
        valid = np.zeros((m,), bool)
        idx_buf = np.full((m,), -1, np.int64)
        shard_K = []
        base, extra = divmod(idx.size, p)
        off = 0
        for q in range(p):
            cnt = base + (1 if q < extra else 0)
            sl = slice(q * m_per, q * m_per + cnt)
            sub = idx[off: off + cnt]
            self._store.fill(buf, sl, sub)
            yb[sl] = y[sub]
            ab[sl] = alpha[sub]
            gb[sl] = gamma[sub]
            valid[sl] = True
            idx_buf[sl] = sub
            if ell:
                shard_K.append(self._store.buffer_K(sub))
            off += cnt
        self._last_shard_K = tuple(shard_K)
        # row identity travels with the buffer only when the row cache needs
        # it — cache-off chunk graphs stay exactly as before
        data = self._store.to_device(
            buf, self._put, gids=idx_buf if self._cache_slots() else None)
        state = smo.init_state(self._put(ab), self._put(gb),
                               self._put(valid))
        return data, self._put(yb), state, idx_buf

    # -- fault tolerance -------------------------------------------------
    def _save_ckpt(self, alpha, gamma, act_full, meta: dict):
        from repro.ckpt import checkpoint as ck
        import os
        d = os.path.join(self.cfg.checkpoint_dir, f"step_{meta['step']}")
        ck.save(d, meta["step"],
                {"svm": {"alpha": alpha, "gamma": gamma,
                         "active": act_full.astype(np.int8)}},
                extra=meta)

    def _load_ckpt(self, n: int):
        from repro.ckpt import checkpoint as ck
        step = ck.latest_step(self.cfg.checkpoint_dir)
        if step is None:
            return None
        import os
        d = os.path.join(self.cfg.checkpoint_dir, f"step_{step}")
        like = {"alpha": np.zeros(n, np.float32),
                "gamma": np.zeros(n, np.float32),
                "active": np.zeros(n, np.int8)}
        g = ck.restore(d, "svm", like)
        man = ck.load_manifest(d)
        return ({k: np.array(v) for k, v in g.items()}, man["extra"])

    # -- main ----------------------------------------------------------------
    def fit(self, X, y: np.ndarray) -> SVMModel:
        """Train on ``(X, y)``. ``X`` is a dense (n, d) matrix, or — with
        ``format='ell'`` — CSR input (``data.sparse.CSRMatrix``, scipy-like
        csr object, or a ``(data, indices, indptr, shape)`` tuple), which
        streams CSR->ELL buffers and never allocates dense X on host."""
        cfg, h = self.cfg, self.h
        t0 = time.perf_counter()
        if spfmt.is_csr_like(X):
            X = spfmt.as_csr(X)      # normalizes scipy-like/tuple forms
        else:
            X = np.ascontiguousarray(X, np.float32)
        y = np.ascontiguousarray(y, np.float32)
        n, d = (int(s) for s in X.shape)
        assert set(np.unique(y)) <= {-1.0, 1.0}, "labels must be +-1"
        self._store = dataplane.make_store(X, cfg.format, cfg.ell_K,
                                           cfg.ell_lane)
        del X                                  # train from the store only

        alpha = np.zeros((n,), np.float32)
        gamma = (-y).astype(np.float32)
        stats = FitStats(min_active=n)

        interval = h.interval(n)
        tol20 = jnp.float32(cfg.recon_eps_factor * cfg.eps)
        tol2 = jnp.float32(2.0 * cfg.eps)

        shrink_on = h.policy != "none"
        recon_count = 0
        t_train = 0.0
        t_recon = 0.0
        stalled = False
        step0, nshr0, act_full0 = 0, 0, None
        if cfg.resume and cfg.checkpoint_dir:
            got = self._load_ckpt(n)
            if got is not None:
                g, meta = got
                alpha, gamma = g["alpha"], g["gamma"]
                act_full0 = g["active"].astype(bool)
                step0 = int(meta["step"])
                nshr0 = int(meta.get("shrink_events", 0))
                recon_count = int(meta.get("recon_count", 0))
                shrink_on = bool(meta.get("shrink_on", shrink_on))
                stats.reconstructions = recon_count

        # Build the runner only after a possible restore: a Single-policy
        # checkpoint taken post-reconstruction carries shrink_on=False, and
        # a runner pre-built with interval > 0 would silently re-enable
        # shrinking on resume (stale gammas, broken Eq. 9 bookkeeping).
        run_interval = interval if shrink_on else 0
        runner = self._runner(cfg, run_interval)

        if act_full0 is not None and shrink_on:
            idx = np.flatnonzero(act_full0)
        else:
            idx = np.arange(n)
        data, yb, state, idx = self._make_buffer(y, alpha, gamma, idx)
        self._note_buffer(stats, data)
        state = state._replace(step=jnp.int32(step0),
                               n_shrinks=jnp.int32(nshr0))
        if run_interval > 0:
            state = state._replace(next_shrink=jnp.int32(step0 + run_interval))
        ckpt_count = 0
        # LRU kernel-row cache (None when off). Never checkpointed: cached
        # rows are exact, so rebuilding it empty on resume is trajectory-
        # neutral. miss_seen tracks the cumulative miss counter so each
        # chunk's flops bill only the rows actually recomputed.
        cache = self._new_cache(data.m)
        miss_seen = 0

        while True:
            tol = tol20 if (shrink_on and recon_count == 0) else tol2
            # ---- inner optimization at current tolerance --------------------
            while True:
                tc = time.perf_counter()
                step_before = int(state.step)
                state, cache = runner(data, yb, state, cache, tol,
                                      min(cfg.chunk_iters,
                                          max(1, cfg.max_iters
                                              - int(state.step))))
                state.converged.block_until_ready()
                t_train += time.perf_counter() - tc
                n_active = int(jnp.sum(state.active))
                stats.min_active = min(stats.min_active, n_active)
                # hot-loop model FLOPs, selection- and cache-aware: each
                # iteration pays the O(M) epilogue (Eq. 6 FMA; wss2 adds the
                # second-order selection sweep), plus one kernel-row pass
                # per row actually computed — 2/iter without the cache, the
                # provider-miss count with it.
                iters_done = int(state.step) - step_before
                if cache is not None:
                    misses_now = int(cache.misses)
                    rows_new = misses_now - miss_seen
                    miss_seen = misses_now
                else:
                    rows_new = 2 * iters_done
                epilogue = 12.0 if cfg.selection == "wss2" else 4.0
                stats.flops_est += (rows_new * data.flops_row_pass()
                                    + iters_done * epilogue) * float(data.m)
                if cfg.checkpoint_dir:
                    ckpt_count += 1
                    if ckpt_count % cfg.checkpoint_every == 0:
                        alpha, gamma = self._writeback(state, idx, alpha,
                                                       gamma)
                        act_full = np.zeros((n,), bool)
                        act_full[idx[(idx >= 0)
                                     & np.asarray(state.active)]] = True
                        self._save_ckpt(alpha, gamma, act_full, {
                            "step": int(state.step),
                            "shrink_events": int(state.n_shrinks),
                            "recon_count": recon_count,
                            "shrink_on": shrink_on})
                if bool(state.converged) or bool(state.stalled) or \
                        int(state.step) >= cfg.max_iters:
                    break
                # physical compaction between chunks (DESIGN.md SS4) — moves
                # rows in the store's native format (ELL: 2K+1 floats/row)
                if shrink_on and n_active < cfg.compact_ratio * data.m \
                        and _bucket(-(-n_active // self._nshards()),
                                    max(cfg.min_buffer // self._nshards(), 8)) \
                        * self._nshards() < data.m:
                    alpha, gamma = self._writeback(state, idx, alpha, gamma)
                    keep_mask = (idx >= 0) & np.asarray(state.active)
                    keep = idx[keep_mask]
                    idx_old = idx
                    data, yb, state2, idx = self._make_buffer(
                        y, alpha, gamma, keep)
                    # survivors keep their global ids -> cached rows are
                    # re-gathered into the compacted geometry, not dropped
                    cache = rowcache.remap_cache(cache, idx_old, idx,
                                                 self._put_cache_vals)
                    state = state2._replace(
                        step=state.step,
                        next_shrink=state.step + max(1, min(interval, keep.size)),
                        n_shrinks=state.n_shrinks)
                    stats.compactions += 1
                    self._note_buffer(stats, data)
            stalled = stalled or bool(state.stalled)
            # n_shrinks is cumulative for the whole run (carried through
            # compactions/reconstructions, restored from checkpoints), so
            # assign — a += here grew quadratically with reconstructions
            # under the Multi policy.
            stats.shrink_events = int(state.n_shrinks)
            alpha, gamma = self._writeback(state, idx, alpha, gamma)

            if not shrink_on or recon_count >= cfg.max_reconstructions \
                    or int(state.step) >= cfg.max_iters:
                break

            # ---- gradient reconstruction + un-shrink (Alg. 5 lines 26-33) --
            tr = time.perf_counter()
            act = np.zeros((n,), bool)
            live = (idx >= 0) & np.asarray(state.active)
            act[idx[live]] = True
            stale = np.flatnonzero(~act)
            gamma[stale] = self._reconstruct(y, alpha, stale)
            t_recon += time.perf_counter() - tr
            recon_count += 1

            # optimality over ALL samples (Eq. 9)
            b_up, b_low = _betas(gamma, alpha, y, cfg.C)
            if b_up + 2.0 * cfg.eps >= b_low:
                state = state._replace(converged=jnp.bool_(True))
                break
            # un-shrink: rebuild full buffer; Single disables shrinking.
            # The grown buffer re-adds rows no cached entry has values for,
            # so remap_cache invalidates here (counters survive).
            step_save, nshr = int(state.step), int(state.n_shrinks)
            idx_old = idx
            data, yb, state, idx = self._make_buffer(
                y, alpha, gamma, np.arange(n))
            cache = rowcache.remap_cache(cache, idx_old, idx,
                                         self._put_cache_vals)
            self._note_buffer(stats, data)
            if h.policy == "single":
                shrink_on = False
                runner = self._runner(cfg, 0)
            else:
                runner = self._runner(cfg, interval)
                state = state._replace(
                    next_shrink=jnp.int32(step_save + interval))
            state = state._replace(step=jnp.int32(step_save),
                                   n_shrinks=jnp.int32(nshr))

        # ---- finalize -------------------------------------------------------
        b_up, b_low = _betas(gamma, alpha, y, cfg.C)
        bnd = cfg.C * smo._BND
        i0 = (alpha > bnd) & (alpha < cfg.C - bnd)
        beta = float(gamma[i0].mean()) if i0.any() else float((b_low + b_up) / 2)
        sv = np.flatnonzero(alpha > 0)
        stats.iterations = int(state.step)
        stats.n_sv = int(sv.size)
        stats.n_bound_sv = int(np.sum(alpha >= cfg.C))
        stats.reconstructions = recon_count
        stats.train_time = t_train
        stats.recon_time = t_recon
        stats.total_time = time.perf_counter() - t0
        stats.converged = bool(b_up + 2 * cfg.eps >= b_low)
        stats.stalled = stalled
        stats.final_gap = float(b_low - b_up)
        if cache is not None:
            stats.cache_hits = int(cache.hits)
            stats.cache_misses = int(cache.misses)
            looked = stats.cache_hits + stats.cache_misses
            stats.cache_hit_rate = stats.cache_hits / looked if looked else 0.0
        coef = (alpha[sv] * y[sv]).astype(np.float32)
        if self._store.fmt == "ell":
            # SV extraction at the SVs' own adaptive K (lane-rounded max
            # extent over the support set) — predict-time memory tracks the
            # model, not the ingest budget.
            sv_vals, sv_cols = self._store.ell_rows(sv)
            return SVMModel(cfg, None, coef, beta, alpha, stats,
                            sv_vals=sv_vals, sv_cols=sv_cols,
                            n_features=self._store.n_features)
        return SVMModel(cfg, self._store.X[sv].copy(), coef, beta, alpha,
                        stats)

    def _note_buffer(self, stats: FitStats, data) -> None:
        """Record buffer geometry: size always; K/shard-K on ELL buffers."""
        stats.buffer_sizes.append(data.m)
        if isinstance(data, dataplane.ELLData):
            stats.buffer_K.append(data.K)
            stats.shard_K.append(self._last_shard_K)

    @staticmethod
    def _writeback(state: smo.SMOState, idx: np.ndarray,
                   alpha: np.ndarray, gamma: np.ndarray):
        ab = np.asarray(state.alpha)
        gb = np.asarray(state.gamma)
        mask = idx >= 0
        alpha[idx[mask]] = ab[mask]
        gamma[idx[mask]] = gb[mask]
        return alpha, gamma


def _betas(gamma: np.ndarray, alpha: np.ndarray, y: np.ndarray, C: float):
    """Eq. 8 on host over all samples (used at reconstruction points)."""
    pos = y > 0
    at0 = alpha <= C * smo._BND
    atc = alpha >= C * (1.0 - smo._BND)
    i0 = ~at0 & ~atc
    in_up = i0 | (pos & at0) | (~pos & atc)
    in_low = i0 | (pos & atc) | (~pos & at0)
    b_up = gamma[in_up].min() if in_up.any() else np.inf
    b_low = gamma[in_low].max() if in_low.any() else -np.inf
    return float(b_up), float(b_low)


def train(X: np.ndarray, y: np.ndarray, **kw) -> SVMModel:
    """Convenience wrapper: repro.core.solver.train(X, y, C=..., sigma2=...)."""
    return SMOSolver(SVMConfig(**kw)).fit(X, y)
