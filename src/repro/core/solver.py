"""Single-host SMO solver — public API + the epoch-driver hook surface.

The shrink -> compact -> reconstruct -> un-shrink -> re-optimize state
machine (the paper's Algorithm 5) lives in :mod:`repro.core.driver` and is
shared verbatim with :class:`repro.core.parallel.ParallelSMOSolver`; this
module owns what is *per-solver*:

  * :class:`SVMConfig` / :class:`SVMModel` — the user-facing configuration
    and trained-model API (predict / decision_function / dual_objective);
  * :class:`SMOSolver` — the single-host hook implementations the driver
    calls: chunk-runner construction (``_runner``), device placement
    (``_put`` / ``_put_full`` / ``_put_cache_vals``), row-cache sizing
    (``_cache_slots`` / ``_new_cache``) and rewarming across un-shrink
    (``_regrow_cache``), Alg. 6 in both backends (``_reconstruct`` —
    host streaming; ``_reconstruct_mirror`` — the jitted scan over the
    device full-set mirror), and compaction sharding pins
    (``_compact_shardings``; None here — single device);
  * model finalize — beta, support-vector extraction in the store's native
    format, and the Eq. 9 convergence verdict over all samples.

``repro.core.parallel`` overrides exactly the placement/runner/
reconstruction hooks to train the same driver over a shard_map mesh —
the Single/Multi policy logic, checkpoint/resume, and physical compaction
exist once, in the driver.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import dataplane, driver
from repro.core import heuristics as H
from repro.core import kernel_fns
from repro.core import mirror as mirror_mod
from repro.core import reconstruct, rowcache, smo
from repro.core.driver import FitStats  # re-export (public API)

__all__ = ["SVMConfig", "SVMModel", "SMOSolver", "FitStats", "train"]


@dataclasses.dataclass
class SVMConfig:
    C: float = 1.0
    kernel: str = "rbf"
    sigma2: float = 1.0          # Gaussian width; K = exp(-||x-z||^2 / (2 sigma^2))
    eps: float = 1e-3            # user tolerance (Eq. 9 uses 2*eps)
    heuristic: "str | H.ShrinkHeuristic" = "original"
    selection: str = "wss1"      # 'wss2': second-order pair selection (the
                                 # paper's stated future work; fewer
                                 # iterations, 2 kernel-row passes/iter)
    format: str = "dense"        # sample storage: 'dense' | 'ell' (block-ELL
                                 # sparse, paper Sec. 2.2; wins memory when
                                 # density < d / 2K)
    ell_K: "int | None" = None   # ELL nonzero budget per row; default = max
                                 # row nnz rounded up to ``ell_lane``; explicit
                                 # values are rounded up to a lane multiple
    ell_lane: int = 128          # TPU lane multiple for the ELL K padding
    ell_adaptive: bool = True    # recompute K from the *surviving* rows at
                                 # every buffer build/compaction (bucketed to
                                 # power-of-two lanes); False pins K to the
                                 # store-wide ingest budget
    row_cache: bool = False      # device-resident kernel-row cache in front
                                 # of the row provider; exact (cache on/off
                                 # trajectories are bit-identical)
    row_cache_slots: int = 64    # cache capacity (rows); bucketed to a power
                                 # of two so it is not a jit retrace dimension
    row_cache_policy: str = "lru"   # eviction: 'lru' | 'slru' (segmented —
                                 # scan-resistant when the working set
                                 # exceeds the slot count; still exact)
    compact_backend: str = "device"  # physical compaction: 'device' (jitted
                                 # jnp.take gather, zero host row traffic) |
                                 # 'host' (store rebuild — the parity oracle)
    mirror: str = "auto"         # device-resident full-set mirror: 'device'
                                 # (jitted Alg. 6 reconstruction + device
                                 # un-shrink; errors if over budget) | 'host'
                                 # (streaming paths — the parity oracle) |
                                 # 'auto' (device when it fits the budget)
    mirror_budget_bytes: "int | None" = None
                                 # per-device byte cap for the mirror;
                                 # default: a fraction of the backend-
                                 # reported device memory (unknown -> fits)
    recon_block: int = 8192      # Alg. 6 SV/query block edge (single-host
                                 # backends; both walk the same grid, so
                                 # this never affects parity — smaller
                                 # blocks bound peak scratch, larger ones
                                 # amortize per-block overhead)
    max_iters: int = 4_000_000
    chunk_iters: int = 256       # jitted while_loop segment length; smaller
                                 # chunks let physical compaction engage
                                 # sooner (-16% gamma-update FLOPs measured
                                 # on a9a; EXPERIMENTS.md section Perf/SVM-2)
    fuse_iters: int = 1          # segments (of up to chunk_iters iterations
                                 # each) fused into ONE device dispatch; the
                                 # epoch summary is the only readback. 1 =
                                 # one segment per dispatch — the bit-exact
                                 # parity oracle for every k > 1 (same XLA
                                 # executable; k rides as a traced scalar).
                                 # Raise to amortize dispatch latency once
                                 # per-iteration compute is small (see
                                 # benchmarks/sparse_bench.py --epoch-out)
    compact_ratio: float = 0.55  # compact buffer when active fraction < this
    min_buffer: int = 256
    recon_eps_factor: float = 20.0  # Alg. 5 line 7 first-reconstruction gate
    use_pallas: bool = False
    max_reconstructions: int = 64   # safety bound for Multi
    checkpoint_dir: "str | None" = None  # save SMO state between chunks
    checkpoint_every: int = 1       # in chunks (= fused-epoch segments);
                                    # fused dispatches clip their segment
                                    # budget to this cadence, so save
                                    # points match the fuse_iters=1 oracle
    resume: bool = False            # restore from checkpoint_dir if present
                                    # (newest COMPLETE step; torn/corrupt
                                    # saves are skipped — see repro.ckpt)
    ckpt_retries: int = 3           # bounded retry-with-backoff attempts
                                    # around checkpoint writes (transient
                                    # FS faults); corruption never retries
    watchdog_threshold: float = 0.0  # straggler watchdog: flag a dispatch
                                    # slower than threshold x the running
                                    # median dispatch time (0 = off). The
                                    # on_straggle policy forces a
                                    # checkpoint at the dispatch boundary
                                    # and halves the fused segment budget
                                    # (bounded blast radius per dispatch)
    watchdog_window: int = 32       # dispatches in the median window
    watchdog_warmup: int = 3        # dispatches before the watchdog arms

    @property
    def inv_2s2(self) -> float:
        return 1.0 / (2.0 * self.sigma2)


@dataclasses.dataclass
class SVMModel:
    config: SVMConfig
    sv_x: "np.ndarray | None"    # (n_sv, d); None when SVs are stored ELL
    sv_coef: np.ndarray          # (n_sv,)  alpha_i * y_i
    beta: float
    alpha: np.ndarray            # (N,) full multipliers (diagnostics)
    stats: FitStats
    sv_vals: "np.ndarray | None" = None   # (n_sv, K) ELL support vectors
    sv_cols: "np.ndarray | None" = None   # (n_sv, K)
    n_features: "int | None" = None       # d (set for ELL models)

    def _sv_kernel_fn(self):
        """jitted z_block -> K(z_block, SVs): the row provider's ``matrix``
        over an SV device buffer in its native storage format."""
        cfg = self.config
        if self.sv_vals is not None:
            # bf16-compacted models upcast at the device boundary: storage
            # rounding is the only difference from an fp32 model
            vals = jnp.asarray(self.sv_vals).astype(jnp.float32)
            data = dataplane.ELLData(vals, jnp.asarray(self.sv_cols),
                                     jnp.sum(vals * vals, axis=-1),
                                     self.n_features)
            fmt = "ell"
        else:
            svx = jnp.asarray(self.sv_x).astype(jnp.float32)
            data = dataplane.DenseData(svx, jnp.sum(svx * svx, axis=-1))
            fmt = "dense"
        provider = kernel_fns.make_provider(cfg.kernel, fmt,
                                            inv_2s2=cfg.inv_2s2)
        return jax.jit(lambda z: provider.matrix(data, z))

    def _sv_dense(self) -> np.ndarray:
        """Support vectors as a dense (n_sv, d) block (query side of K)."""
        if self.sv_vals is None:
            return np.asarray(self.sv_x, np.float32)
        store = dataplane.ELLStore(self.sv_vals, self.sv_cols,
                                   self.n_features)
        return store.dense_rows(np.arange(self.sv_vals.shape[0]))

    def serve_engine(self, **kw) -> "object":
        """The model's scoring engine (``core.serve.ServeEngine``), built
        lazily and cached per keyword spec — ``decision_function`` /
        ``predict`` route through the default spec (single device, model
        storage dtype, Pallas iff the model was trained with it)."""
        from repro.core import serve
        key = tuple(sorted(kw.items()))
        cache = self.__dict__.setdefault("_engines", {})
        if key not in cache:
            kw.setdefault("use_pallas", self.config.use_pallas)
            cache[key] = serve.ServeEngine(self, **kw)
        return cache[key]

    def decision_function(self, Z: np.ndarray) -> np.ndarray:
        """Decision scores through the serving engine: device-resident
        SVs, pow2 microbatch buckets, fused accumulate dispatches; accepts
        dense (n, d) or CSR-like queries. ``decision_function_host`` is
        the seed-era host block loop, kept as the parity oracle."""
        return self.serve_engine().decision_function(Z)

    def decision_function_host(self, Z: np.ndarray,
                               block: int = 8192) -> np.ndarray:
        """Host-loop scoring oracle: materializes K(z_block, SV) through
        the provider's ``matrix`` and contracts on device, one fixed-size
        block at a time. Kept as the bit-parity reference the serve plane
        is tested against (fp32 engines match it to float tolerance; bf16
        engines to storage-rounding tolerance)."""
        Z = np.asarray(Z, np.float32)
        coef = jnp.asarray(self.sv_coef, jnp.float32)
        beta = jnp.asarray(self.beta, jnp.float32)
        # (n_sv, K) coef table (multi-problem union model) -> (n, K) scores
        out = np.empty((Z.shape[0],) + tuple(coef.shape[1:]), np.float32)
        kf = self._sv_kernel_fn()
        f = jax.jit(lambda z: kf(z) @ coef - beta)
        for s in range(0, Z.shape[0], block):
            zb = Z[s: s + block]
            pad = block - zb.shape[0]
            if pad:
                zb = np.pad(zb, ((0, pad), (0, 0)))
            out[s: s + min(block, Z.shape[0] - s)] = np.asarray(
                f(jnp.asarray(zb)))[: Z.shape[0] - s]
        return out

    def predict(self, Z: np.ndarray) -> np.ndarray:
        return np.where(self.decision_function(Z) >= 0.0, 1.0, -1.0)

    def compact(self, dedup: bool = True,
                dtype: "str | None" = None) -> "SVMModel":
        """Deployment-artifact shrink: drop zero-coef SVs, optionally merge
        duplicate SV rows (coefs add — exact, the kernel rows are equal),
        optionally store SV values in bfloat16 (half the resident bytes;
        scores then differ from fp32 by one storage rounding of the SVs —
        the tradeoff ``BENCH_serve.json`` measures). Returns a new model
        scoring identically (fp32) through the same engine/oracle paths.
        """
        coef = np.asarray(self.sv_coef, np.float32).copy()
        if self.sv_vals is not None:
            rows = np.ascontiguousarray(
                np.concatenate([np.asarray(self.sv_vals, np.float32),
                                np.asarray(self.sv_cols, np.float32)],
                               axis=1))
        else:
            rows = np.ascontiguousarray(np.asarray(self.sv_x, np.float32))
        if dedup and rows.shape[0]:
            # bit-view uniquing: rows are duplicates only when bitwise
            # equal, which is exactly when their kernel rows coincide
            view = rows.view(np.uint32).reshape(rows.shape[0], -1)
            _, first, inv = np.unique(view, axis=0, return_index=True,
                                      return_inverse=True)
            merged = np.zeros((first.size,), np.float32)
            np.add.at(merged, inv.reshape(-1), coef)
            keep_rows, coef = np.sort(first), merged[
                np.argsort(first, kind="stable")]
        else:
            keep_rows = np.arange(rows.shape[0])
        nz = coef != 0.0
        keep_rows, coef = keep_rows[nz], coef[nz]
        store_dt = np.float32
        if dtype in ("bf16", "bfloat16"):
            store_dt = np.dtype(jnp.bfloat16)
        elif dtype not in (None, "float32", "fp32", "f32"):
            raise ValueError(f"unsupported SV storage dtype {dtype!r}")
        if self.sv_vals is not None:
            return SVMModel(
                self.config, None, coef, self.beta, self.alpha, self.stats,
                sv_vals=np.asarray(self.sv_vals)[keep_rows].astype(store_dt),
                sv_cols=np.asarray(self.sv_cols, np.int32)[keep_rows],
                n_features=self.n_features)
        return SVMModel(self.config,
                        np.asarray(self.sv_x)[keep_rows].astype(store_dt),
                        coef, self.beta, self.alpha, self.stats)

    def dual_objective(self) -> float:
        """L_D (Eq. 1) over the support set — used by tests/benchmarks."""
        K = np.asarray(self._sv_kernel_fn()(jnp.asarray(self._sv_dense())))
        a = np.abs(self.sv_coef)           # alpha (coef = alpha*y)
        return float(a.sum() - 0.5 * self.sv_coef @ K @ self.sv_coef)


_RUNNER_CACHE: dict = {}


class SMOSolver:
    """Single-host SMO with adaptive shrinking, trained through the shared
    :class:`repro.core.driver.EpochDriver`. See ``repro.core.parallel`` for
    the shard_map multi-device version (same driver, different hooks)."""

    def __init__(self, config: SVMConfig):
        self.cfg = config
        self.h = H.get(config.heuristic)

    # -- backend hooks (overridden by repro.core.parallel) ----------------
    def _cache_slots(self) -> int:
        """Row-cache capacity: 0 when disabled, else power-of-two bucketed
        so user-tuned values do not each get their own XLA executable."""
        if not self.cfg.row_cache:
            return 0
        return rowcache.bucket_slots(self.cfg.row_cache_slots)

    def _put_cache_vals(self, arr: np.ndarray):
        """Placement for the (slots, M) cache value table; the parallel
        subclass shards it over the mesh on the buffer axis."""
        return jnp.asarray(arr)

    def _new_cache(self, m: int):
        slots = self._cache_slots()
        if slots == 0:
            return None
        return rowcache.init_cache(slots, m, self._put_cache_vals)

    def _runner(self, cfg: SVMConfig, interval: int):
        # the eviction policy is dead code in a cache-off runner — pin it in
        # the key so 'lru' and 'slru' cache-off fits share one executable
        policy = cfg.row_cache_policy if self._cache_slots() else "lru"
        key = (cfg.kernel, cfg.C, cfg.inv_2s2, interval, cfg.use_pallas,
               cfg.selection, cfg.format, self._cache_slots(), policy)
        if key not in _RUNNER_CACHE:
            _RUNNER_CACHE[key] = smo.make_chunk_runner(
                cfg.kernel, cfg.C, cfg.inv_2s2, interval, cfg.use_pallas,
                selection=cfg.selection, fmt=cfg.format,
                cache_slots=self._cache_slots(), cache_policy=policy)
        return _RUNNER_CACHE[key]

    def _reconstruct(self, y, alpha, stale):
        """Alg. 6 for global row indices ``stale``; the host-streaming
        backend (``mirror='host'`` / auto fallback — the parity oracle).
        Consumes the data-plane store, so ELL storage streams densified
        blocks instead of materializing a dense X."""
        return reconstruct.reconstruct_gamma_store(
            self.cfg.kernel, self._store, y, alpha, stale, self.cfg.inv_2s2,
            row_block=self.cfg.recon_block, sv_block=self.cfg.recon_block,
            ell_adaptive=self.cfg.ell_adaptive)

    def _reconstruct_mirror(self, mir, alpha_d, gamma_d, sv_rows, stale):
        """Alg. 6 as one jitted scan over the device mirror, accumulating
        into the donated (n,) gamma master — replays the host oracle's
        exact block plan (``reconstruct.plan_blocks`` / shared K_sv /
        shared ``recon_block`` island), so the two backends are
        bit-identical. Returns the updated gamma master."""
        cfg, store = self.cfg, self._store
        provider = kernel_fns.make_provider(cfg.kernel, store.fmt,
                                            inv_2s2=cfg.inv_2s2)
        K_sv = (reconstruct.sv_lane_budget(store, sv_rows, cfg.ell_adaptive)
                if store.fmt == "ell" else None)
        sv_blk, nsb = reconstruct.plan_blocks(sv_rows.size, cfg.recon_block)
        row_blk, nrb = reconstruct.plan_blocks(stale.size, cfg.recon_block)
        sv_pos = mirror_mod.pad_pos(
            mir.pos_of[sv_rows].astype(np.int32), nsb * sv_blk)
        stale_pos = mirror_mod.pad_pos(
            mir.pos_of[stale].astype(np.int32), nrb * row_blk)
        return mirror_mod.reconstruct_device(
            provider, mir.data, mir.y, alpha_d, gamma_d,
            jnp.asarray(sv_pos), jnp.asarray(stale_pos), jnp.asarray(False),
            sv_blk=sv_blk, row_blk=row_blk, nsb=nsb, nrb=nrb, K_sv=K_sv,
            n=mir.n)

    def _regrow_cache(self, cache, data, pairs: bool, n: int):
        """Rewarm the row cache across un-shrink growth (see
        ``rowcache.regrow_cache``). The provider must match the chunk
        runner's exactly — including the Pallas backend flag — so warmed
        bits equal in-loop miss bits."""
        provider = kernel_fns.make_provider(self.cfg.kernel, self._store.fmt,
                                            self.cfg.use_pallas,
                                            self.cfg.inv_2s2)
        return rowcache.regrow_cache(cache, data, provider, pairs, n)

    def _nshards(self) -> int:
        return 1

    def _put(self, arr: np.ndarray):
        """Device placement hook; the parallel subclass shards over the mesh."""
        return jnp.asarray(arr)

    def _put_full(self, arr: np.ndarray):
        """Placement for the (n,) alpha/gamma device masters the compaction
        pipeline scatters into; replicated over the mesh in parallel."""
        return jnp.asarray(arr)

    def _compact_shardings(self):
        """Output-sharding pins for the device compaction step — None on a
        single device; the parallel subclass returns its mesh layout."""
        return None

    # -- main -------------------------------------------------------------
    def fit(self, X, y: np.ndarray) -> SVMModel:
        """Train on ``(X, y)`` via the shared epoch driver. ``X`` is a
        dense (n, d) matrix, or — with ``format='ell'`` — CSR input
        (``data.sparse.CSRMatrix``, scipy-like csr object, or a
        ``(data, indices, indptr, shape)`` tuple), which streams CSR->ELL
        buffers and never allocates dense X on host."""
        cfg = self.cfg
        alpha, gamma, y, stats = driver.EpochDriver(self).fit(X, y)

        # ---- finalize: beta, SV extraction, Eq. 9 verdict ----------------
        b_up, b_low = driver.betas(gamma, alpha, y, cfg.C)
        bnd = cfg.C * smo._BND
        i0 = (alpha > bnd) & (alpha < cfg.C - bnd)
        beta = float(gamma[i0].mean()) if i0.any() else float((b_low + b_up) / 2)
        sv = np.flatnonzero(alpha > 0)
        stats.n_sv = int(sv.size)
        stats.n_bound_sv = int(np.sum(alpha >= cfg.C))
        stats.converged = bool(b_up + 2 * cfg.eps >= b_low)
        stats.final_gap = float(b_low - b_up)
        coef = (alpha[sv] * y[sv]).astype(np.float32)
        if self._store.fmt == "ell":
            # SV extraction at the SVs' own adaptive K (lane-rounded max
            # extent over the support set) — predict-time memory tracks the
            # model, not the ingest budget.
            sv_vals, sv_cols = self._store.ell_rows(sv)
            return SVMModel(cfg, None, coef, beta, alpha, stats,
                            sv_vals=sv_vals, sv_cols=sv_cols,
                            n_features=self._store.n_features)
        return SVMModel(cfg, self._store.X[sv].copy(), coef, beta, alpha,
                        stats)


def train(X: np.ndarray, y: np.ndarray, **kw) -> SVMModel:
    """Convenience wrapper: repro.core.solver.train(X, y, C=..., sigma2=...)."""
    return SMOSolver(SVMConfig(**kw)).fit(X, y)
