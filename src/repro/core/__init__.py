"""Core: the paper's contribution — parallel SMO with adaptive shrinking."""
from repro.core.heuristics import TABLE3, ShrinkHeuristic, get as get_heuristic
from repro.core.serve import ServeEngine
from repro.core.solver import SVMConfig, SVMModel, SMOSolver, FitStats, train
from repro.core.multi import (MultiProblemDriver, OvRSVMModel, ovr_tasks,
                              train_ovr)

__all__ = [
    "TABLE3", "ShrinkHeuristic", "get_heuristic", "ServeEngine",
    "SVMConfig", "SVMModel", "SMOSolver", "FitStats", "train",
    "MultiProblemDriver", "OvRSVMModel", "ovr_tasks", "train_ovr",
]
