"""Small shared numeric utilities for the SMO stack.

Power-of-two bucketing is load-bearing everywhere a host-side size becomes
a jit trace dimension (buffer rows M, reconstruction blocks, ring row
blocks): bucketing keeps the XLA executable cache at O(log N) entries per
runner instead of one per distinct size. The three hand-rolled copies that
used to live in ``solver``, ``parallel`` and ``reconstruct`` are
consolidated here so the rounding semantics cannot drift apart.
"""
from __future__ import annotations


def next_pow2(n: int) -> int:
    """Smallest power of two >= ``n`` (1 for n <= 1)."""
    return 1 << max(0, int(n) - 1).bit_length()


def bucket_pow2(n: int, lo: int, hi: int = 1 << 30) -> int:
    """Power-of-two bucket of ``n`` clamped to [``lo``, ``hi``].

    ``n <= 0`` returns ``lo`` (an empty gather still needs a non-degenerate
    buffer shape). ``lo``/``hi`` need not themselves be powers of two; the
    clamp applies after rounding.
    """
    if n <= 0:
        return lo
    return min(max(lo, next_pow2(n)), hi)
