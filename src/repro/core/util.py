"""Small shared numeric utilities for the SMO stack.

Power-of-two bucketing is load-bearing everywhere a host-side size becomes
a jit trace dimension (buffer rows M, reconstruction blocks, ring row
blocks): bucketing keeps the XLA executable cache at O(log N) entries per
runner instead of one per distinct size. The three hand-rolled copies that
used to live in ``solver``, ``parallel`` and ``reconstruct`` are
consolidated here so the rounding semantics cannot drift apart.

The ``*_device`` twins below are the jit-traceable versions of the same
rules, used by the fused epoch runner to evaluate the compaction predicate
(``need_compact``) on device without a host round-trip. They must agree
with the host functions on every int32 input — the fused runner exits its
segment loop exactly when the host would have compacted, so a single
disagreement desynchronizes the k>1 trajectory from the k=1 oracle
(``tests/test_fused_epoch.py`` sweeps the equivalence).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def next_pow2(n: int) -> int:
    """Smallest power of two >= ``n`` (1 for n <= 1)."""
    return 1 << max(0, int(n) - 1).bit_length()


def bucket_pow2(n: int, lo: int, hi: int = 1 << 30) -> int:
    """Power-of-two bucket of ``n`` clamped to [``lo``, ``hi``].

    ``n <= 0`` returns ``lo`` (an empty gather still needs a non-degenerate
    buffer shape). ``lo``/``hi`` need not themselves be powers of two; the
    clamp applies after rounding.
    """
    if n <= 0:
        return lo
    return min(max(lo, next_pow2(n)), hi)


def next_pow2_device(n: jax.Array) -> jax.Array:
    """Traced int32 twin of :func:`next_pow2` (bit-smear cascade — exact
    integer arithmetic, no float rounding). Valid for n < 2**30."""
    v = jnp.maximum(n.astype(jnp.int32), 1) - 1
    for sh in (1, 2, 4, 8, 16):
        v = v | (v >> sh)
    return v + 1


def bucket_pow2_device(n: jax.Array, lo: jax.Array,
                       hi: int = 1 << 30) -> jax.Array:
    """Traced int32 twin of :func:`bucket_pow2`; same clamp semantics."""
    p2 = next_pow2_device(n)
    return jnp.where(n <= 0, lo, jnp.minimum(jnp.maximum(lo, p2), hi))
