"""Device-resident full-set mirror of the training data.

The shrink -> compact -> reconstruct -> un-shrink epoch cycle of Alg. 5 had
two host round-trips left after the device compaction pipeline landed:
Alg. 6 reconstruction streamed every SV / stale-row block through host
numpy, and every un-shrink rebuilt the full buffer from the host store.
Both exist only because the full training set was not resident on device.
This module keeps it resident: one fill of the store at fit time into the
*exact* balanced p-shard buffer layout ``EpochDriver._make_buffer`` would
produce for the full set (dense, or block-ELL at the full set's adaptive
lane budget; under ``ParallelSMOSolver`` sharded over the mesh on the
sample axis), after which

  * **buffer (re)builds** — the initial buffer, resume-subset builds, and
    un-shrink growth — become one jitted device gather
    (:func:`grow_step`): the same ``dataplane.compact_plan`` /
    ``gather_rows`` machinery as device compaction, sourced from the
    mirror instead of the outgoing buffer, with alpha/gamma gathered from
    the device (n,) masters. Zero host row traffic.
  * **Alg. 6 reconstruction** (:func:`reconstruct_device`) becomes a
    ``lax.scan`` over mirror SV blocks with an inner ``fori_loop`` over
    stale-row query blocks, replaying the host-streaming path's exact
    uniform block plan (``reconstruct.plan_blocks`` / ``sv_lane_budget``)
    through the same ``kernel_fns.recon_block`` barrier/cond island, and
    accumulating gamma straight into the donated device (n,) master.

Bit-exactness contract
----------------------
``SVMConfig(mirror='host')`` keeps the host-streaming paths
(``reconstruct.reconstruct_gamma_store`` + host store rebuilds) as the
parity oracle, bit-identical to the mirror paths by construction — the
same contract (and test style) as ``compact_backend='host'``. The three
load-bearing pieces: mirror rows are verbatim copies of store rows (so
device gathers produce the bits host fills produce), squared norms are
gathered from the ONE store-level ``sq_rows`` array on every path (never
re-summed per buffer shape), and the reconstruction block compute is the
shared degenerate-cond island that codegens identically standalone and
inside a scan (see ``kernel_fns.recon_block``).

Sizing (``SVMConfig(mirror='auto'|'device'|'host')``)
-----------------------------------------------------
The mirror is sized at fit time (:func:`resolve`): ``'auto'`` falls back
to the host-streaming paths when the full-set mirror will not fit the
per-device budget (``SVMConfig.mirror_budget_bytes``, defaulting to a
fraction of the backend-reported device memory; unknown backends — CPU —
are assumed to fit, their "device" memory being host RAM). ``'device'``
raises a clear error instead of OOMing mid-fit — the CSR-ingest case the
lane budget makes easy to hit: a full-set ELL mirror costs
``n * K_full * 8`` bytes even when the CSR form is tiny.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from repro.core import dataplane, kernel_fns, smo, util


# Fraction of the backend-reported per-device memory the mirror may claim:
# it shares the device with the training buffer (~mirror-sized at the first
# epoch), the cache value table, and XLA scratch.
_BUDGET_FRACTION = 0.4


@dataclasses.dataclass
class Mirror:
    """Device-resident full training set in driver buffer layout."""
    data: object            # DenseData | ELLData — m = p * m_per rows
    y: jax.Array            # (m,) f32 labels by mirror position (+1 on pad)
    idx: np.ndarray         # (m,) i64 host map: position -> global id (-1 pad)
    pos_of: np.ndarray      # (n,) i64 inverse map: global id -> position
    p: int
    m_per: int
    K: Optional[int]        # full-set ELL lane budget (None for dense)
    n: int
    nbytes: int


def full_m_per(count: int, p: int, min_buffer: int) -> int:
    """Per-shard slot count for a ``count``-row buffer — the ONE rounding
    rule (`driver._make_buffer`, compaction scheduling, and the mirror all
    use it, so mirror geometry == host-rebuild geometry). Because it is a
    pure function of (count, p), buffer/mirror geometry is NEVER part of
    a checkpoint: an elastic restore onto a different device count just
    recomputes it for the new p (see the recovery diagram in driver)."""
    return util.bucket_pow2(-(-count // p), max(min_buffer // p, 8))


def mirror_nbytes(store, p: int, m_per: int, K: Optional[int]) -> int:
    """Device bytes of the full-set mirror (rows + sq_norms + gids + y)."""
    m = p * m_per
    if store.fmt == "ell":
        return m * (int(K) * 8 + 12)
    return m * (store.n_features * 4 + 12)


def budget_bytes(cfg) -> Optional[int]:
    """Per-device mirror budget: the explicit config cap, else a fraction
    of the backend-reported device memory, else None (unknown -> fits)."""
    if cfg.mirror_budget_bytes is not None:
        return int(cfg.mirror_budget_bytes)
    try:
        stats = jax.local_devices()[0].memory_stats() or {}
    except Exception:
        stats = {}
    limit = stats.get("bytes_limit")
    return int(_BUDGET_FRACTION * limit) if limit else None


def resolve(cfg, store, p: int, shrink_on: bool) -> tuple:
    """Decide the mirror mode at fit time: ``(mode, m_per, K, nbytes)``.

    ``mode`` is 'device' or 'host'. Shrink-free runs ('none' policy, or a
    resume restored past the Single policy's un-shrink) never reconstruct
    or grow, so the mirror would be dead weight — they resolve to 'host'.
    ``mirror='device'`` over budget raises with the numbers spelled out
    instead of OOMing mid-fit; ``'auto'`` falls back to 'host'.
    """
    if cfg.mirror not in ("auto", "device", "host"):
        raise ValueError(f"unknown mirror {cfg.mirror!r} "
                         "(want 'auto', 'device' or 'host')")
    m_per = full_m_per(store.n, p, cfg.min_buffer)
    if cfg.mirror == "host" or not shrink_on:
        return "host", m_per, None, 0
    K = None
    if store.fmt == "ell":
        all_rows = np.arange(store.n)
        from repro.data import sparse as spfmt
        K = (spfmt.bucket_lanes(store.buffer_K(all_rows), store.lane,
                                cap=store.K)
             if cfg.ell_adaptive else store.K)
    need = mirror_nbytes(store, p, m_per, K) // p    # sharded over p devices
    cap = budget_bytes(cfg)
    if cap is not None and need > cap:
        if cfg.mirror == "device":
            raise ValueError(
                f"mirror='device' needs {need} bytes/device "
                f"(n={store.n}, fmt={store.fmt}"
                + (f", ELL lane budget K={K}" if K is not None else "")
                + f") but the device-memory cap is {cap} bytes; "
                "use mirror='auto' (host-streaming fallback) or raise "
                "mirror_budget_bytes")
        return "host", m_per, K, need
    return "device", m_per, K, need


def build(store, y: np.ndarray, put, p: int, m_per: int,
          K: Optional[int]) -> Mirror:
    """One host fill of the full set into the driver's balanced buffer
    layout — the only host->device row traffic a mirrored fit performs."""
    n = store.n
    m = p * m_per
    buf = store.alloc(m, K)
    yb = np.ones((m,), np.float32)
    sqb = np.zeros((m,), np.float32)
    idx, pos_of = dataplane.full_layout(np.arange(n), p, m_per)
    for sl, sub in dataplane.deal(np.arange(n), p, m_per):
        store.fill(buf, sl, sub)
        yb[sl] = y[sub]
        sqb[sl] = store.sq_rows(sub)
    data = store.to_device(buf, put, gids=idx, sq=sqb)
    return Mirror(data=data, y=put(yb), idx=idx, pos_of=pos_of, p=p,
                  m_per=m_per, K=K, n=n,
                  nbytes=mirror_nbytes(store, p, m_per, K))


# --------------------------------------------------------------------------
# Buffer builds from the mirror (initial, resume-subset, un-shrink growth).


@functools.partial(
    jax.jit, static_argnames=("p", "m_per", "K_new", "shards"))
def grow_step(data, y_m, alpha_d, gamma_d, keep_pos, n_sel,
              *, p, m_per, K_new, shards):
    """Gather a fresh training buffer for the rows marked in ``keep_pos``
    (a (m_mirror,) bool mask by mirror position) out of the device mirror:
    the un-shrink growth step, and — with a subset mask — the initial /
    resume buffer build. Rows, gids and sq_norms come from the mirror
    (``compact_plan`` + ``gather_rows``, exactly the device-compaction
    machinery, so the balanced layout is the host rebuild's bit-for-bit);
    alpha/gamma come from the (n,) device masters, which hold every row's
    latest value (drop-time values for shrunk rows, reconstruction output
    for stale rows). Nothing is donated — the mirror and masters persist.
    Returns ``(data, y_buf, fresh state)``; the driver patches the step
    counters like it does after a host rebuild.
    """
    src, valid = dataplane.compact_plan(keep_pos, n_sel, p, m_per)
    data2 = dataplane.gather_rows(data, src, valid, K_new)
    yb2 = jnp.where(valid, y_m[src], 1.0)       # padding: y=+1, alpha=0 -> I1
    gid = jnp.where(valid, data2.gids, 0)
    alpha2 = jnp.where(valid, alpha_d[gid], 0.0)
    gamma2 = jnp.where(valid, gamma_d[gid], jnp.float32(jnp.inf))
    state = smo.init_state(alpha2, gamma2, valid)
    out = (data2, yb2, state)
    if shards is not None:
        wsc = lax.with_sharding_constraint
        if isinstance(data2, dataplane.ELLData):
            data2 = dataplane.ELLData(
                wsc(data2.vals, shards.rows), wsc(data2.cols, shards.rows),
                wsc(data2.sq_norms, shards.vec), data2.n_features,
                wsc(data2.gids, shards.vec))
        else:
            data2 = dataplane.DenseData(
                wsc(data2.X, shards.rows), wsc(data2.sq_norms, shards.vec),
                wsc(data2.gids, shards.vec))
        vec = lambda a: wsc(a, shards.vec)
        rep = lambda a: wsc(a, shards.rep)
        state = state._replace(
            alpha=vec(state.alpha), gamma=vec(state.gamma),
            active=vec(state.active), beta_up=rep(state.beta_up),
            beta_low=rep(state.beta_low), i_up=rep(state.i_up),
            i_low=rep(state.i_low), step=rep(state.step),
            next_shrink=rep(state.next_shrink), n_shrinks=rep(state.n_shrinks),
            converged=rep(state.converged), stalled=rep(state.stalled))
        out = (data2, wsc(yb2, shards.vec), state)
    return out


# --------------------------------------------------------------------------
# Device-side Alg. 6 (single-host backend; the parallel solver feeds the
# mirror through its ppermute ring instead — see parallel.py).


def _sv_block(data, pos, valid, K_sv):
    """SV block by mirror positions, native format, padding rows zeroed —
    the device analogue of ``store.alloc`` + ``store.fill`` over the SV
    subset (ELL rows truncate exactly: nonzeros pack a slot prefix and
    every SV extent is <= K_sv by construction of the shared budget)."""
    safe = jnp.where(valid, pos, 0)
    sq = jnp.where(valid, data.sq_norms[safe], 0.0)
    if isinstance(data, dataplane.DenseData):
        return dataplane.DenseData(
            jnp.where(valid[:, None], data.X[safe], 0.0), sq)
    vals = jnp.where(valid[:, None], data.vals[safe, :K_sv], 0.0)
    cols = jnp.where(valid[:, None], data.cols[safe, :K_sv], 0)
    return dataplane.ELLData(vals, cols, sq, data.n_features)


def _dense_block(data, pos, valid):
    """Stale-row query block, densified from the mirror — the device
    analogue of ``store.dense_rows``. The scatter-add is exact: each real
    column appears once per row, duplicate padding columns add 0.0."""
    safe = jnp.where(valid, pos, 0)
    if isinstance(data, dataplane.DenseData):
        return jnp.where(valid[:, None], data.X[safe], 0.0)
    vals = jnp.where(valid[:, None], data.vals[safe], 0.0)
    cols = jnp.where(valid[:, None], data.cols[safe], 0)
    B = pos.shape[0]
    return jnp.zeros((B, data.n_features), jnp.float32).at[
        jnp.arange(B)[:, None], cols].add(vals)


@functools.partial(
    jax.jit,
    static_argnames=("provider", "sv_blk", "row_blk", "nsb", "nrb", "K_sv",
                     "n"),
    donate_argnames=("gamma_d",))
def reconstruct_device(provider, data, y_m, alpha_d, gamma_d, sv_pos,
                       stale_pos, never, *, sv_blk, row_blk, nsb, nrb,
                       K_sv, n):
    """Alg. 6 as ONE jitted program over the mirror: ``lax.scan`` over SV
    blocks (outer, so the block grid walks in the host oracle's order),
    ``fori_loop`` over stale-row query blocks (inner), the shared
    ``kernel_fns.recon_block`` island per cell, gamma scattered into the
    donated (n,) master. ``sv_pos`` / ``stale_pos`` are mirror positions
    padded with -1 to ``nsb * sv_blk`` / ``nrb * row_blk`` — the only
    per-reconstruction host->device traffic (index vectors, never rows).
    """
    acc0 = jnp.zeros((nrb * row_blk,), jnp.float32)

    def sv_step(acc, pos):
        valid = pos >= 0
        svd = _sv_block(data, pos, valid, K_sv)
        safe = jnp.where(valid, pos, 0)
        gid = jnp.where(valid, data.gids[safe], 0)
        coef = jnp.where(valid, alpha_d[gid] * y_m[safe], 0.0)

        def rb(i, acc):
            bpos = lax.dynamic_slice(stale_pos, (i * row_blk,), (row_blk,))
            Zi = _dense_block(data, bpos, bpos >= 0)
            g = kernel_fns.recon_block(provider, svd, Zi, coef, never)
            s = i * row_blk
            return lax.dynamic_update_slice(
                acc, lax.dynamic_slice(acc, (s,), (row_blk,)) + g, (s,))

        return lax.fori_loop(0, nrb, rb, acc), None

    acc, _ = lax.scan(sv_step, acc0, sv_pos.reshape(nsb, sv_blk))
    valid = stale_pos >= 0
    safe = jnp.where(valid, stale_pos, 0)
    gnew = acc - y_m[safe]
    tgt = jnp.where(valid, data.gids[safe], n)
    return gamma_d.at[tgt].set(gnew, mode="drop")


def pad_pos(pos: np.ndarray, total: int) -> np.ndarray:
    """Pad a position vector with -1 up to ``total`` (i32, contiguous)."""
    out = np.full((total,), -1, np.int32)
    out[: pos.size] = pos
    return out
