"""Gradient reconstruction — the paper's Algorithm 6.

Recomputes gamma_i = sum_{j : alpha_j > 0} alpha_j y_j K(x_i, x_j) - y_i for
samples whose gamma went stale while shrunk. Cost is |X - A| * |SV| kernel
evaluations — "the bottleneck in achieving the overall speedup" (Sec. 3.4) —
so the driver triggers it only at the 20-eps / 2-eps thresholds of Alg. 5,
and Single/Multi policies bound how often it runs.

Shapes are bucketed (next power of two) so jit recompiles O(log N) times at
most across a whole training run.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import kernel_fns


def _bucket(n: int, lo: int = 128) -> int:
    return max(lo, 1 << (int(n - 1)).bit_length()) if n > 0 else lo


@functools.partial(jax.jit, static_argnames=("kernel", "block"))
def _recon_block(kernel: str, Xi, yi, Xsv, coef, inv_2s2, block: int = 0):
    """gamma for rows Xi given padded SV set (coef = alpha*y, 0 on padding)."""
    K = kernel_fns.full_kernel_matrix(kernel, Xi, Xsv, inv_2s2)
    return K @ coef - yi


def reconstruct_gamma(kernel: str, X: np.ndarray, y: np.ndarray,
                      alpha: np.ndarray, rows: np.ndarray, inv_2s2: float,
                      row_block: int = 8192) -> np.ndarray:
    """Return reconstructed gamma values for ``rows`` (global indices).

    Host-side orchestration: gathers the support-vector set (alpha > 0 —
    includes bound SVs at alpha = C, the false-positive class the paper
    worries about), pads to a bucket, streams row blocks through a jitted
    matmul. Mirrors Alg. 6's loop structure with the q-th-CPU loop replaced
    by row-block streaming.
    """
    if rows.size == 0:
        return np.zeros((0,), np.float32)
    sv_idx = np.flatnonzero(alpha > 0.0)
    if sv_idx.size == 0:
        return (-y[rows]).astype(np.float32)

    nsv_pad = _bucket(sv_idx.size)
    Xsv = np.zeros((nsv_pad, X.shape[1]), X.dtype)
    Xsv[: sv_idx.size] = X[sv_idx]
    coef = np.zeros((nsv_pad,), np.float32)
    coef[: sv_idx.size] = (alpha[sv_idx] * y[sv_idx]).astype(np.float32)

    Xsv_d = jnp.asarray(Xsv)
    coef_d = jnp.asarray(coef)

    out = np.empty((rows.size,), np.float32)
    for s in range(0, rows.size, row_block):
        blk = rows[s: s + row_block]
        nb = _bucket(blk.size)
        Xi = np.zeros((nb, X.shape[1]), X.dtype)
        Xi[: blk.size] = X[blk]
        yi = np.zeros((nb,), np.float32)
        yi[: blk.size] = y[blk]
        g = _recon_block(kernel, jnp.asarray(Xi), jnp.asarray(yi),
                         Xsv_d, coef_d, jnp.float32(inv_2s2))
        out[s: s + blk.size] = np.asarray(g)[: blk.size]
    return out


def reconstruct_gamma_store(kernel: str, store, y: np.ndarray,
                            alpha: np.ndarray, rows: np.ndarray,
                            inv_2s2: float, row_block: int = 8192,
                            sv_block: int = 8192) -> np.ndarray:
    """Alg. 6 over a data-plane store (dense, block-ELL, or CSR).

    Dense stores delegate to :func:`reconstruct_gamma`. ELL-family stores
    (``ELLStore``/``CSRStore``) densify *blocks* on the fly — (row_block, d)
    stale rows x (sv_block, d) support vectors — so storage stays sparse and
    peak dense scratch is bounded by the block sizes, never N*d (the paper's
    Fig. 1b memory argument holds through reconstruction, including for
    CSR-ingested datasets that never had a dense host form).
    """
    if store.fmt == "dense":
        return reconstruct_gamma(kernel, store.X, y, alpha, rows, inv_2s2,
                                 row_block)
    if rows.size == 0:
        return np.zeros((0,), np.float32)
    sv_idx = np.flatnonzero(alpha > 0.0)
    if sv_idx.size == 0:
        return (-y[rows]).astype(np.float32)

    d = store.n_features
    out = np.empty((rows.size,), np.float32)
    for s in range(0, rows.size, row_block):
        blk = rows[s: s + row_block]
        nb = _bucket(blk.size)
        Xi = np.zeros((nb, d), np.float32)
        Xi[: blk.size] = store.dense_rows(blk)
        Xi_d = jnp.asarray(Xi)
        acc = np.zeros((nb,), np.float32)
        for t in range(0, sv_idx.size, sv_block):
            sub = sv_idx[t: t + sv_block]
            nsv = _bucket(sub.size)
            Xsv = np.zeros((nsv, d), np.float32)
            Xsv[: sub.size] = store.dense_rows(sub)
            coef = np.zeros((nsv,), np.float32)
            coef[: sub.size] = (alpha[sub] * y[sub]).astype(np.float32)
            acc += np.asarray(_recon_block(
                kernel, Xi_d, jnp.zeros((nb,), jnp.float32),
                jnp.asarray(Xsv), jnp.asarray(coef), jnp.float32(inv_2s2)))
        out[s: s + blk.size] = acc[: blk.size] - y[blk]
    return out
