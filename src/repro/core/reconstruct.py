"""Gradient reconstruction — the paper's Algorithm 6 (host-streaming path).

Recomputes gamma_i = sum_{j : alpha_j > 0} alpha_j y_j K(x_i, x_j) - y_i for
samples whose gamma went stale while shrunk. Cost is |X - A| * |SV| kernel
evaluations — "the bottleneck in achieving the overall speedup" (Sec. 3.4) —
so the driver triggers it only at the 20-eps / 2-eps thresholds of Alg. 5,
and Single/Multi policies bound how often it runs.

This module is the HOST-STREAMING backend and the parity oracle: every SV
and stale-row block is built in host numpy (``store.fill`` /
``store.dense_rows``) and shipped to the device per block. The default
training path (``SVMConfig(mirror='auto'|'device')``) instead runs Alg. 6
as one jitted program over the device-resident full-set mirror
(``repro.core.mirror``) with zero per-block host transfers; the two are
bit-identical — the same uniform block plan (:func:`plan_blocks`, shared
K_sv from :func:`sv_lane_budget`, shared ``store.sq_rows`` provenance for
squared norms) drives both, and the per-block compute is the single
barrier/cond island ``kernel_fns.recon_block`` in both backends, exactly
like ``compact_backend='host'`` oracles the device compaction step.

Kernel blocks go through the row-provider layer (``kernel_fns.make_provider``)
against an SV device buffer built in the host store's *native* format: dense
stores ship a ``DenseData`` block, ELL-family stores an ``ELLData`` block at
the SV set's adaptive lane budget — the support-vector side of Alg. 6
never densifies. Only the (row_block, d) stale-row query side travels dense,
mirroring the chunk runners' "working-set rows travel dense" rule.

Block shapes are uniform per call (count padded up to a whole number of
power-of-two-bucketed blocks) so jit recompiles O(log N) times at most —
and so the device-mirror backend can drive the identical decomposition
from a ``lax.scan``.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import kernel_fns, util
from repro.data import sparse as spfmt


def plan_blocks(count: int, cap: int, lo: int = 128) -> tuple:
    """Uniform block decomposition of ``count`` items: ``(blk, n_blocks)``
    with ``blk`` a power-of-two bucket (<= ``cap``) and ``count`` padded up
    to ``blk * n_blocks``. Shared by the host-streaming loop and the
    device-mirror scan so both walk byte-identical block shapes."""
    blk = min(int(cap), util.bucket_pow2(count, lo))
    return blk, max(1, -(-int(count) // blk))


def sv_lane_budget(store, sv_idx: np.ndarray, adaptive: bool = True) -> int:
    """The support-vector blocks' shared ELL lane budget: the SV set's own
    lane-rounded max extent, power-of-two bucketed (``ell_adaptive=False``
    pins it to the store budget). One value for ALL SV blocks of a
    reconstruction — a per-block budget would make the device scan's
    shapes ragged."""
    if not adaptive:
        return store.K
    return spfmt.bucket_lanes(store.buffer_K(sv_idx), store.lane,
                              cap=store.K)


@functools.partial(jax.jit, static_argnames=("provider",))
def _recon_block(provider, sv_data, Zi, coef, never):
    """Host-path wrapper of the shared block island (see
    ``kernel_fns.recon_block`` for why the cond/barrier structure is
    load-bearing)."""
    return kernel_fns.recon_block(provider, sv_data, Zi, coef, never)


def reconstruct_gamma_store(kernel: str, store, y: np.ndarray,
                            alpha: np.ndarray, rows: np.ndarray,
                            inv_2s2: float, row_block: int = 8192,
                            sv_block: int = 8192,
                            ell_adaptive: bool = True) -> np.ndarray:
    """Alg. 6 over a data-plane store (dense, block-ELL, or CSR).

    Host-side orchestration: gathers the support-vector set (alpha > 0 —
    includes bound SVs at alpha = C, the false-positive class the paper
    worries about) into native-format device blocks, densifies
    (row_block, d) stale-row query blocks on the fly, and streams both
    through the shared ``kernel_fns.recon_block`` island. Peak dense
    scratch is bounded by the block sizes, never N*d (the paper's Fig. 1b
    memory argument holds through reconstruction, including for
    CSR-ingested datasets that never had a dense host form). Mirrors
    Alg. 6's loop structure with the q-th-CPU loop replaced by block
    streaming; the device-mirror backend replays the identical plan on
    device (``repro.core.mirror.reconstruct_device``).
    """
    if rows.size == 0:
        return np.zeros((0,), np.float32)
    sv_idx = np.flatnonzero(alpha > 0.0)
    if sv_idx.size == 0:
        return (-y[rows]).astype(np.float32)

    provider = kernel_fns.make_provider(kernel, store.fmt, inv_2s2=inv_2s2)
    d = store.n_features
    ell = store.fmt == "ell"
    K_sv = sv_lane_budget(store, sv_idx, ell_adaptive) if ell else None
    sv_blk, nsb = plan_blocks(sv_idx.size, sv_block)
    row_blk, nrb = plan_blocks(rows.size, row_block)
    never = jnp.asarray(False)

    # SV blocks are the OUTER loop so at most one native-format SV device
    # block is live at a time — peak device memory stays bounded by
    # (sv_blk, row_blk) even when the support set itself outgrows device
    # memory (the rcv1/webspam-scale regime the CSR data plane targets).
    # Each SV block is built exactly once. The device-mirror backend scans
    # the same (nsb, nrb) grid in the same order, so the acc additions
    # below associate identically.
    acc = np.zeros((nrb * row_blk,), np.float32)
    for t in range(nsb):
        sub = sv_idx[t * sv_blk: (t + 1) * sv_blk]
        buf = store.alloc(sv_blk, K_sv)
        store.fill(buf, slice(0, sub.size), sub)
        sq = np.zeros((sv_blk,), np.float32)
        sq[: sub.size] = store.sq_rows(sub)
        coef = np.zeros((sv_blk,), np.float32)
        coef[: sub.size] = (alpha[sub] * y[sub]).astype(np.float32)
        sv_data = store.to_device(buf, jnp.asarray, sq=sq)
        coef_d = jnp.asarray(coef)
        for s in range(nrb):
            blk = rows[s * row_blk: (s + 1) * row_blk]
            Zi = np.zeros((row_blk, d), np.float32)
            Zi[: blk.size] = store.dense_rows(blk)
            g = np.asarray(_recon_block(provider, sv_data, jnp.asarray(Zi),
                                        coef_d, never))
            acc[s * row_blk: (s + 1) * row_blk] += g
    return acc[: rows.size] - y[rows]


def reconstruct_gamma(kernel: str, X: np.ndarray, y: np.ndarray,
                      alpha: np.ndarray, rows: np.ndarray, inv_2s2: float,
                      row_block: int = 8192) -> np.ndarray:
    """Dense-matrix convenience wrapper around
    :func:`reconstruct_gamma_store` (kept for callers that hold a plain
    (n, d) array rather than a data-plane store)."""
    from repro.core import dataplane
    return reconstruct_gamma_store(kernel, dataplane.DenseStore(X), y,
                                   alpha, rows, inv_2s2, row_block)
