"""Gradient reconstruction — the paper's Algorithm 6.

Recomputes gamma_i = sum_{j : alpha_j > 0} alpha_j y_j K(x_i, x_j) - y_i for
samples whose gamma went stale while shrunk. Cost is |X - A| * |SV| kernel
evaluations — "the bottleneck in achieving the overall speedup" (Sec. 3.4) —
so the driver triggers it only at the 20-eps / 2-eps thresholds of Alg. 5,
and Single/Multi policies bound how often it runs.

Kernel blocks go through the row-provider layer (``kernel_fns.make_provider``)
against an SV device buffer built in the host store's *native* format: dense
stores ship a ``DenseData`` block, ELL-family stores an ``ELLData`` block at
the SV subset's own adaptive lane budget — the support-vector side of Alg. 6
never densifies. Only the (row_block, d) stale-row query side travels dense,
mirroring the chunk runners' "working-set rows travel dense" rule.

Shapes are bucketed (next power of two) so jit recompiles O(log N) times at
most across a whole training run.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import kernel_fns, util
from repro.data import sparse as spfmt


def _bucket(n: int, lo: int = 128) -> int:
    return util.bucket_pow2(n, lo)


@functools.partial(jax.jit, static_argnames=("provider",))
def _recon_block(provider, sv_data, Zi, coef):
    """Partial gamma for query rows Zi given an SV buffer in its native
    storage format (coef = alpha*y, 0 on padding rows)."""
    return provider.matrix(sv_data, Zi) @ coef


def reconstruct_gamma_store(kernel: str, store, y: np.ndarray,
                            alpha: np.ndarray, rows: np.ndarray,
                            inv_2s2: float, row_block: int = 8192,
                            sv_block: int = 8192) -> np.ndarray:
    """Alg. 6 over a data-plane store (dense, block-ELL, or CSR).

    Host-side orchestration: gathers the support-vector set (alpha > 0 —
    includes bound SVs at alpha = C, the false-positive class the paper
    worries about) into native-format device blocks, densifies
    (row_block, d) stale-row query blocks on the fly, and streams both
    through the provider's ``matrix``. Peak dense scratch is bounded by the
    block sizes, never N*d (the paper's Fig. 1b memory argument holds
    through reconstruction, including for CSR-ingested datasets that never
    had a dense host form). Mirrors Alg. 6's loop structure with the
    q-th-CPU loop replaced by block streaming.
    """
    if rows.size == 0:
        return np.zeros((0,), np.float32)
    sv_idx = np.flatnonzero(alpha > 0.0)
    if sv_idx.size == 0:
        return (-y[rows]).astype(np.float32)

    provider = kernel_fns.make_provider(kernel, store.fmt, inv_2s2=inv_2s2)
    d = store.n_features
    ell = store.fmt == "ell"

    # SV blocks are the OUTER loop so at most one native-format SV device
    # block is live at a time — peak device memory stays bounded by
    # (sv_block, row_block) even when the support set itself outgrows
    # device memory (the rcv1/webspam-scale regime the CSR data plane
    # targets). Each SV block is built exactly once.
    acc = np.zeros((rows.size,), np.float32)
    for t in range(0, sv_idx.size, sv_block):
        sub = sv_idx[t: t + sv_block]
        nsv = _bucket(sub.size)
        K = None
        if ell:
            # the SV subset's own lane budget, power-of-two bucketed so a
            # drifting support set re-specializes O(log K) times
            K = spfmt.bucket_lanes(store.buffer_K(sub), store.lane,
                                   cap=store.K)
        buf = store.alloc(nsv, K)
        store.fill(buf, slice(0, sub.size), sub)
        coef = np.zeros((nsv,), np.float32)
        coef[: sub.size] = (alpha[sub] * y[sub]).astype(np.float32)
        sv_data = store.to_device(buf, jnp.asarray)
        coef_d = jnp.asarray(coef)
        for s in range(0, rows.size, row_block):
            blk = rows[s: s + row_block]
            nb = _bucket(blk.size)
            Zi = np.zeros((nb, d), np.float32)
            Zi[: blk.size] = store.dense_rows(blk)
            g = np.asarray(_recon_block(provider, sv_data, jnp.asarray(Zi),
                                        coef_d))
            acc[s: s + blk.size] += g[: blk.size]
    return acc - y[rows]


def reconstruct_gamma(kernel: str, X: np.ndarray, y: np.ndarray,
                      alpha: np.ndarray, rows: np.ndarray, inv_2s2: float,
                      row_block: int = 8192) -> np.ndarray:
    """Dense-matrix convenience wrapper around
    :func:`reconstruct_gamma_store` (kept for callers that hold a plain
    (n, d) array rather than a data-plane store)."""
    from repro.core import dataplane
    return reconstruct_gamma_store(kernel, dataplane.DenseStore(X), y,
                                   alpha, rows, inv_2s2, row_block)
