"""SMO (Keerthi Modification-2) fused-epoch inner loop with in-loop
adaptive shrinking.

Implements the paper's Algorithm 1 (sequential view) as a jit-compiled
``lax.while_loop``. One iteration:

  1. working-set selection (Eq. 8): worst KKT violators over the *active* set,
  2. analytic pair update (Eq. 11/12) with joint box clipping (Eq. 2),
  3. gradient (gamma) update (Eq. 6) for every sample in the chunk buffer,
  4. shrink rule (Eq. 10) when the heuristic counter fires (Alg. 4),
  5. optimality test (Eq. 9).

Fused multi-iteration epochs
----------------------------
One dispatch of the runner built by :func:`make_chunk_runner` executes up
to ``k`` *segments*. Each segment is exactly one legacy chunk: selection
re-establishment at entry, then up to ``chunk_iters`` iterations in the
inner ``lax.while_loop`` carrying the full (SMOState, RowCache) pytree.
Between segments an *outer* while loop evaluates, on device, every
decision the host used to read scalars back for: the hard exits
(Eq. 9 convergence, the stall guard, the global iteration budget) and the
physical-compaction predicate — ``n_active < compact_lt`` with
``compact_lt = ceil(compact_ratio * m)`` plus "the rebuilt buffer would
really be smaller", both exact integer twins of the host rule
(:func:`repro.core.util.bucket_pow2_device`). The dispatch returns a
fixed-size :class:`EpochSummary`; the host reads THAT, once, and nothing
else.

Dispatch timeline (device above the line, host below)::

        +-- seg 1 --+-- seg 2 --+ ... +-- seg s --+ summary
        | select_pair re-establish; <= chunk_iters iterations;
        | post-segment exit tests: converged | stalled |
        | step >= max_iters | need_compact  -> stop fusing
  ------+-------------------------------------------------+---------
   one jit dispatch; k, chunk_iters,          one EpochSummary readback:
   max_iters, compact_lt, mper_lo all         step / segs / n_active /
   ride as TRACED i32 scalars                 min_active / n_shrinks /
                                              converged / stalled /
                                              need_compact / cache hits+
                                              misses / (p,) shard_ext
   host decides only at epoch boundaries: checkpoint save, compaction
   geometry (n_active + shard_ext from the summary), reconstruction.

Because every schedule scalar is traced, ``fuse_iters=1`` runs the SAME
XLA executable as any k>1 — one executable per buffer geometry serves
every schedule, so the bit-exact k>1 == k=1 contract reduces to segment
*scheduling*: the device stops fusing exactly where the legacy host loop
would have intervened (hard exit or compaction), and the driver aligns
checkpoint boundaries via ``heuristics.fuse_budget``.

Shapes are static under jit: shrinking inside the chunk is *mask-based*
(restricts selection, as in the paper); the FLOP reduction the paper gets
from eliminating samples is realized by *physical compaction* between
dispatches (see ``driver.py`` — a device-side gather), because XLA
requires static shapes. gamma is maintained for every sample currently
resident in the (compacted) buffer — the paper makes the same choice
("gamma ... is maintained for all the samples in the training
set/non-shrunk samples", Sec. 2.2.1).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import dataplane, kernel_fns, rowcache, util

_INF = jnp.float32(jnp.inf)
_TAU = 1e-12  # libsvm-style guard for non-PD pair curvature
_BND = 1e-6   # relative tolerance for at-bound classification: a sample
              # whose alpha lands within C*_BND of a bound is treated as AT
              # the bound (Eq. 7 sets), else a float-boundary sample can be
              # selected as a worst violator with no room to move -> stall


class SMOState(NamedTuple):
    """Per-chunk solver state. Arrays are the (possibly compacted) buffer."""
    alpha: jax.Array        # (M,) f32
    gamma: jax.Array        # (M,) f32  — Eq. 5
    active: jax.Array       # (M,) bool — False for shrunk + padding rows
    beta_up: jax.Array      # f32
    beta_low: jax.Array     # f32
    i_up: jax.Array         # i32 index into buffer
    i_low: jax.Array        # i32
    step: jax.Array         # i32, global iteration counter
    next_shrink: jax.Array  # i32, iteration of next shrink-rule application
    n_shrinks: jax.Array    # i32, shrink events so far (this chunk run)
    converged: jax.Array    # bool — Eq. 9 at the chunk's tolerance
    stalled: jax.Array      # bool — progress guard tripped


class EpochSummary(NamedTuple):
    """Fixed-size per-dispatch readback of the fused epoch runner — the
    ONLY host<->device traffic of the optimization hot loop. Every
    decision the driver makes between dispatches (hard exit, checkpoint
    save, compaction geometry) is a function of these scalars plus the
    (p,) shard extents; buffer arrays cross the link only at checkpoint
    and reconstruction boundaries."""
    step: jax.Array          # i32 — global iteration counter after the epoch
    segs: jax.Array          # i32 — segments actually run (<= k)
    n_active: jax.Array      # i32 — active count after the last segment
    min_active: jax.Array    # i32 — min of the per-segment active counts
    n_shrinks: jax.Array     # i32 — cumulative shrink events
    converged: jax.Array     # bool — Eq. 9 at the dispatch tolerance
    stalled: jax.Array       # bool — progress guard tripped
    need_compact: jax.Array  # bool — device-evaluated compaction predicate
    cache_hits: jax.Array    # i32 — cumulative row-cache hits (0: cache off)
    cache_misses: jax.Array  # i32 — cumulative row-cache misses
    shard_ext: jax.Array     # (p,) i32 — per-shard surviving ELL extents,
                             # computed only when need_compact (else zeros)


def select_pair(gamma: jax.Array, alpha: jax.Array, y: jax.Array,
                active: jax.Array, C: float):
    """Working-set selection, Eq. 8 over index sets Eq. 7.

    I_up = I0 u I1 u I2, I_low = I0 u I3 u I4. Returns
    (beta_up, i_up, beta_low, i_low). Deterministic lowest-index tie-break
    (argmin/argmax of masked arrays).
    """
    pos = y > 0
    at_zero = alpha <= C * _BND
    at_c = alpha >= C * (1.0 - _BND)
    interior = (~at_zero) & (~at_c)                     # I0
    in_up = active & (interior | (pos & at_zero) | (~pos & at_c))
    in_low = active & (interior | (pos & at_c) | (~pos & at_zero))

    g_up = jnp.where(in_up, gamma, _INF)
    g_low = jnp.where(in_low, gamma, -_INF)
    i_up = jnp.argmin(g_up)
    i_low = jnp.argmax(g_low)
    return g_up[i_up], i_up, g_low[i_low], i_low


def shrink_rule(gamma: jax.Array, alpha: jax.Array, y: jax.Array,
                active: jax.Array, beta_up: jax.Array, beta_low: jax.Array,
                C: float) -> jax.Array:
    """Eq. 10: drop bound samples that cannot re-enter the working set.

      i in I3 u I4 with gamma_i < beta_up  -> shrink
      i in I1 u I2 with gamma_i > beta_low -> shrink
    """
    pos = y > 0
    at_zero = alpha <= C * _BND
    at_c = alpha >= C * (1.0 - _BND)
    i12 = (pos & at_zero) | (~pos & at_c)
    i34 = (pos & at_c) | (~pos & at_zero)
    drop = (i34 & (gamma < beta_up)) | (i12 & (gamma > beta_low))
    return active & ~drop


def pair_update(alpha_up, alpha_low, y_up, y_low, g_up, g_low, k_ul, k_uu, k_ll, C):
    """Analytic two-variable solve, Eq. 11/12, with joint L/H clipping that
    preserves sum(alpha*y) exactly and keeps both alphas in [0, C].

    The whole solve is ONE barriered scalar island: the chain
    ``alpha_low - y_low * (g_up - g_low) / rho`` is FMA-contractable, and
    XLA's contraction choice depends on the surrounding program — the
    shard-local parallel executable and the full-buffer single-host
    executable were observed to disagree by 1 ulp on a single update
    (which a later selection can amplify into a different trajectory).
    Isolating the subgraph pins one contraction for every runner that
    inlines this solve.
    """
    args = tuple(jnp.asarray(a, jnp.float32)
                 for a in (alpha_up, alpha_low, y_up, y_low, g_up, g_low,
                           k_ul, k_uu, k_ll, C))
    (alpha_up, alpha_low, y_up, y_low, g_up, g_low, k_ul, k_uu, k_ll,
     C) = lax.optimization_barrier(args)
    rho = 2.0 * k_ul - k_uu - k_ll          # Eq. 12 (== -eta, negative for PD)
    rho = jnp.minimum(rho, -_TAU)
    a_low_unc = alpha_low - y_low * (g_up - g_low) / rho

    s = y_up * y_low
    same = s > 0
    lo = jnp.where(same, jnp.maximum(0.0, alpha_up + alpha_low - C),
                   jnp.maximum(0.0, alpha_low - alpha_up))
    hi = jnp.where(same, jnp.minimum(C, alpha_up + alpha_low),
                   jnp.minimum(C, C + alpha_low - alpha_up))
    a_low_new = jnp.clip(a_low_unc, lo, hi)
    a_up_new = alpha_up + s * (alpha_low - a_low_new)
    a_up_new = jnp.clip(a_up_new, 0.0, C)   # exact box (guards fp drift)
    return lax.optimization_barrier((a_up_new, a_low_new))


def wss2_scores(gamma, alpha, y, active, C, g_up, row_up, kdiag, k_uu):
    """Second-order selection scores for i_low (the paper's stated future
    work; Fan-Chen-Lin 2005 / libsvm WSS2): among violators j in I_low with
    gamma_j > gamma_up, score = b^2/a where b = gamma_j - gamma_up and
    a = K_uu + K_jj - 2 K_uj; -inf elsewhere. Returned as a full (M,) array
    so the parallel runner can argmax locally and compare shard winners."""
    pos = y > 0
    at_zero = alpha <= C * _BND
    at_c = alpha >= C * (1.0 - _BND)
    interior = (~at_zero) & (~at_c)
    in_low = active & (interior | (pos & at_c) | (~pos & at_zero))
    b = gamma - g_up
    a = jnp.maximum(k_uu + kdiag - 2.0 * row_up, _TAU)
    return jnp.where(in_low & (b > 0), b * b / a, -_INF)


# ---------------------------------------------------------------------------
# Batched multi-problem twins (leading problem axis K).
#
# The functions below are elementwise/per-row twins of the scalar working-set
# machinery above, generalized to K concurrent binary problems stacked on a
# leading axis: state arrays are (K, M), per-problem scalars are (K,). They
# are consumed by the fused multi-problem runner in ``repro.core.multi``.
#
# Exactness: every operation is either elementwise f32 (bit-deterministic,
# identical to its scalar twin) or an exact-comparison argmin/argmax with
# first-index tie-break — so given bit-identical inputs per problem, each
# problem's selection/update/shrink is bit-identical to running the scalar
# functions on that problem alone. Per-problem box constants C_k arrive as
# host-precomputed f32 arrays (``box_thresholds``) that reproduce the scalar
# closures' weak-type promotion bits: the scalar path computes ``C * _BND``
# in f64 (python floats) and rounds ONCE to f32 at the compare.
# ---------------------------------------------------------------------------

def box_thresholds(Cs):
    """Host helper: per-problem (K,) f32 box constants (thr0, thr1, Cv) with
    thr0 = f32(C*_BND), thr1 = f32(C*(1-_BND)), Cv = f32(C) — each product
    computed in f64 and rounded once, matching the scalar closures."""
    import numpy as np
    Cs = np.asarray(Cs, np.float64)
    return (np.asarray(Cs * _BND, np.float32),
            np.asarray(Cs * (1.0 - _BND), np.float32),
            np.asarray(Cs, np.float32))


def _index_sets_multi(alpha, y, active, thr0, thr1):
    pos = y > 0
    at_zero = alpha <= thr0[:, None]
    at_c = alpha >= thr1[:, None]
    interior = (~at_zero) & (~at_c)                     # I0
    in_up = active & (interior | (pos & at_zero) | (~pos & at_c))
    in_low = active & (interior | (pos & at_c) | (~pos & at_zero))
    return in_up, in_low


def select_pair_multi(gamma, alpha, y, active, thr0, thr1):
    """Eq. 8 over K problems at once: (K,M) state -> per-problem
    (beta_up, i_up, beta_low, i_low), each (K,). Same deterministic
    lowest-index tie-break as :func:`select_pair`."""
    in_up, in_low = _index_sets_multi(alpha, y, active, thr0, thr1)
    g_up = jnp.where(in_up, gamma, _INF)
    g_low = jnp.where(in_low, gamma, -_INF)
    i_up = jnp.argmin(g_up, axis=1).astype(jnp.int32)
    i_low = jnp.argmax(g_low, axis=1).astype(jnp.int32)
    kk = jnp.arange(gamma.shape[0])
    return g_up[kk, i_up], i_up, g_low[kk, i_low], i_low


def shrink_rule_multi(gamma, alpha, y, active, beta_up, beta_low, thr0, thr1):
    """Eq. 10 over K problems: per-problem logical shrink masks."""
    pos = y > 0
    at_zero = alpha <= thr0[:, None]
    at_c = alpha >= thr1[:, None]
    i12 = (pos & at_zero) | (~pos & at_c)
    i34 = (pos & at_c) | (~pos & at_zero)
    drop = ((i34 & (gamma < beta_up[:, None]))
            | (i12 & (gamma > beta_low[:, None])))
    return active & ~drop


def pair_update_multi(alpha_up, alpha_low, y_up, y_low, g_up, g_low,
                      k_ul, k_uu, k_ll, Cv):
    """Eq. 11/12 per problem lane; one per-lane scalar call per problem
    (the multi runners python-unroll over lanes). Delegates to
    :func:`pair_update` so every runner — scalar, parallel, batched
    single-host, batched sharded — inlines the SAME barriered island and
    XLA cannot contract the solve differently per executable."""
    return pair_update(alpha_up, alpha_low, y_up, y_low, g_up, g_low,
                       k_ul, k_uu, k_ll, Cv)


def wss2_scores_multi(gamma, alpha, y, active, thr0, thr1,
                      g_up, rows_up, kdiag, k_uu):
    """Second-order i_low scores for K problems: (K,M) state + per-problem
    i_up rows ``rows_up`` (K,M) -> (K,M) score table (argmax per row)."""
    _, in_low = _index_sets_multi(alpha, y, active, thr0, thr1)
    b = gamma - g_up[:, None]
    a = jnp.maximum(k_uu[:, None] + kdiag[None, :] - 2.0 * rows_up, _TAU)
    return jnp.where(in_low & (b > 0), b * b / a, -_INF)


def make_chunk_runner(kernel: str, C: float, inv_2s2: float,
                      shrink_interval: int, use_pallas: bool = False,
                      shrink_min_interval: int = 1, selection: str = "wss1",
                      fmt: str = "dense", cache_slots: int = 0,
                      cache_policy: str = "lru", nshards: int = 1):
    """Build the jitted fused-epoch runner::

        state, cache, summary = run_epoch(data, y, state, cache, tol,
                                          k, chunk_iters, max_iters,
                                          compact_lt, mper_lo)

    which runs up to ``k`` segments of up to ``chunk_iters`` SMO
    iterations each — stopping a segment on beta_up + tol >= beta_low
    over the active set (Eq. 9) or the stall guard, and stopping the
    epoch on any hard exit or the moment the device-side compaction
    predicate fires (see module docstring). All six schedule scalars are
    traced i32/f32, so one executable per buffer geometry serves every
    schedule — ``fuse_iters=1`` and ``fuse_iters=k`` literally share code.

    ``nshards`` (static) is the shard count the compaction predicate and
    the (p,) ``shard_ext`` summary lane are computed for; the single-host
    solver passes 1.

    ``shrink_interval`` <= 0 disables in-loop shrinking (the paper's
    "Original" baseline, Alg. 3). The next shrink fires after
    min(shrink_interval, n_active) further iterations (Sec. 3.3.1).

    ``selection``: 'wss1' = the paper's maximal-violating pair (Eq. 8);
    'wss2' = second-order pair selection — fewer iterations at the price of
    two single-row passes per iteration instead of one fused two-row pass
    (the selection of i_low depends on the i_up row).

    ``fmt`` selects the sample storage the chunk consumes: 'dense' takes a
    ``dataplane.DenseData`` buffer, 'ell' a ``dataplane.ELLData`` one (the
    paper's sparse-format storage, Sec. 2.2). Working-set rows travel dense
    either way — O(d) per iteration — while the M-row kernel sweeps stay in
    the buffer's native format. All row production goes through the
    row-provider layer (``kernel_fns.make_provider``), which hides the
    fmt x backend combination behind one protocol.

    ``cache_slots`` > 0 threads a device-resident LRU kernel-row cache
    (``repro.core.rowcache``) through the loop: the chunk takes and returns
    a ``RowCache`` pytree, serves Eq. 6 rows from it on hit, and recomputes
    them with the exact cache-off provider kernels on miss — trajectories
    are bit-identical either way. With ``cache_slots == 0`` the cache
    argument is passed as None and the fused no-cache paths run unchanged.
    ``cache_policy`` selects the eviction policy ('lru' | 'slru'); policies
    only change which rows stay cached, never their values, so the bitwise
    trajectory contract holds for both.

    Nothing here closes over buffer geometry: M, and for ELL buffers the
    lane budget K, are trace dimensions of the jitted chunk, so one runner
    (one ``_RUNNER_CACHE`` entry in the driver) serves every compaction —
    adaptive-K recompaction just re-specializes the XLA executable per
    (M_bucket, K_bucket) pair, both power-of-two bucketed by the driver so
    the cache stays O(log M * log K) per runner, not one entry per
    compaction. Cache capacity is likewise bucketed (power-of-two slots).
    """
    row1 = kernel_fns.get_row(kernel)
    kself = kernel_fns.self_kernel(kernel)
    provider = kernel_fns.make_provider(kernel, fmt, use_pallas, inv_2s2)
    cached = cache_slots > 0

    @functools.partial(jax.jit, donate_argnums=(2, 3))
    def run_epoch(data, y, state: SMOState, cache, tol: jax.Array,
                  k: jax.Array, chunk_iters: jax.Array, max_iters: jax.Array,
                  compact_lt: jax.Array, mper_lo: jax.Array):
        m = data.m

        if selection == "wss2":
            kdiag = provider.diag(data)

        # Row access with structural parity between the cached and uncached
        # executables — the shared factory is load-bearing for the bitwise
        # exactness contract (see rowcache.make_accessors).
        get_row1, get_rows2 = rowcache.make_accessors(
            provider, data, cached, tol < 0.0, cache_policy)

        def body(carry):
            s, c = carry
            iu = s.i_up
            x_up = data.dense_row(iu)
            y_up = y[iu]
            a_up = s.alpha[iu]
            k_uu = kself(x_up, inv_2s2)

            if selection == "wss2":
                row_up, c = get_row1(c, data.gids[iu] if cached else None,
                                     x_up)                      # (M,)
                scores = wss2_scores(s.gamma, s.alpha, y, s.active, C,
                                     s.beta_up, row_up, kdiag, k_uu)
                il = jnp.argmax(scores)
                g_low = s.gamma[il]
            else:
                il = s.i_low
                g_low = s.beta_low
            x_low = data.dense_row(il)
            y_low = y[il]
            a_low = s.alpha[il]

            z2 = jnp.stack([x_up, x_low])                       # (2, d)
            if selection == "wss2":
                # K(x_up, x_low) is already in hand: it is the i_low entry
                # of the selection row the second-order scores were built
                # from (produced through the shared provider/cache), so the
                # update reuses it instead of recomputing the O(d) dot.
                # Exact under the cache contract: row_up is bit-identical
                # cache-on and cache-off, hence so is this scalar — and the
                # update now prices the pair with the same K value the
                # selection scored it by.
                k_ul = row_up[il]
            else:
                # K(x_up, x_low) directly from the two rows — O(d), avoids
                # depending on the full kernel-row computation. The barriers
                # pin this scalar island to an identical isolated subgraph in
                # every runner variant: without them XLA contracts the
                # dot/FMA chain differently depending on surrounding fusion
                # (observed 1-ulp k_ul drift between the cached and uncached
                # executables), which would break the cache-on == cache-off
                # exactness contract.
                xu_b, xl_b = lax.optimization_barrier((x_up, x_low))
                k_ul = lax.optimization_barrier(
                    row1(xl_b[None, :], jnp.sum(xl_b * xl_b)[None],
                         xu_b, inv_2s2)[0])
            k_ll = kself(x_low, inv_2s2)

            a_up_new, a_low_new = pair_update(
                a_up, a_low, y_up, y_low, s.beta_up, g_low,
                k_ul, k_uu, k_ll, C)
            d_up = a_up_new - a_up
            d_low = a_low_new - a_low
            stalled = (jnp.abs(d_up) < _TAU) & (jnp.abs(d_low) < _TAU)

            alpha = s.alpha.at[iu].set(a_up_new).at[il].set(a_low_new)
            # Eq. 6 — fused dual-row FMA; gamma kept for every buffer row.
            coef2 = jnp.stack([y_up * d_up, y_low * d_low])
            if selection == "wss2":
                # the i_up row is already in hand from selection; one more
                # single-row pass finishes Eq. 6 (both rows cacheable)
                row_low, c = get_row1(c, data.gids[il] if cached else None,
                                      x_low)
                gamma = s.gamma + coef2[0] * row_up + coef2[1] * row_low
            elif use_pallas and not cached:
                # fused one-HBM-pass Pallas kernel; no exactness contract
                # with the (rows2 + FMA) cached path on this backend
                gamma = provider.gamma_update(data, s.gamma, z2, coef2)
            else:
                gid2 = (jnp.stack([data.gids[iu], data.gids[il]])
                        if cached else None)
                rows, c = get_rows2(c, gid2, z2)                # (M, 2)
                gamma = provider.gamma_from_rows(s.gamma, rows, coef2)

            # Alg. 4 / Sec. 3.3.1: apply Eq. 10 when the counter fires.
            step1 = s.step + 1
            do_shrink = (shrink_interval > 0) & (step1 >= s.next_shrink)
            active = lax.cond(
                do_shrink,
                lambda: shrink_rule(gamma, alpha, y, s.active,
                                    s.beta_up, s.beta_low, C),
                lambda: s.active)
            n_active = jnp.sum(active)
            interval = jnp.maximum(
                jnp.minimum(jnp.int32(shrink_interval), n_active),
                shrink_min_interval)
            next_shrink = jnp.where(do_shrink, step1 + interval, s.next_shrink)
            n_shrinks = s.n_shrinks + do_shrink.astype(jnp.int32)

            b_up, i_up, b_low, i_low = select_pair(gamma, alpha, y, active, C)
            converged = b_up + tol >= b_low
            return (SMOState(alpha, gamma, active, b_up, b_low, i_up, i_low,
                             step1, next_shrink, n_shrinks, converged,
                             stalled), c)

        def run_segment(s: SMOState, c):
            # Segment entry == legacy dispatch entry: (re)establish
            # selection/convergence for the current buffer and clear the
            # stall latch. Idempotent for a continuing segment (the body
            # just ended on the same select_pair over the same state) and
            # exactly the old semantics after a host-side compaction or
            # reconstruction.
            b_up, i_up, b_low, i_low = select_pair(s.gamma, s.alpha, y,
                                                   s.active, C)
            s = s._replace(beta_up=b_up, i_up=i_up, beta_low=b_low,
                           i_low=i_low, converged=b_up + tol >= b_low,
                           stalled=jnp.bool_(False))
            start = s.step
            # The host used to size each dispatch as
            # min(chunk_iters, max(1, max_iters - step)); same rule, traced.
            lim = jnp.minimum(chunk_iters, jnp.maximum(1, max_iters - start))

            def cond(carry):
                s, _ = carry
                return (~s.converged) & (~s.stalled) & (s.step - start < lim)

            return lax.while_loop(cond, body, (s, c))

        def epoch_cond(carry):
            _, _, segs, _, done, _, _ = carry
            return (~done) & (segs < k)

        def epoch_body(carry):
            s, c, segs, min_act, _, _, _ = carry
            s, c = run_segment(s, c)
            n_act = jnp.sum(s.active).astype(jnp.int32)
            min_act = jnp.minimum(min_act, n_act)
            hard = s.converged | s.stalled | (s.step >= max_iters)
            if shrink_interval > 0:
                # Device twin of the host compaction test: n_active below
                # ceil(compact_ratio * m) AND the rebuilt buffer would be
                # genuinely smaller after per-shard pow2 bucketing. Exact
                # integer arithmetic on both sides, so the epoch stops
                # fusing precisely where the legacy host loop compacted.
                m_per_new = util.bucket_pow2_device(
                    (n_act + nshards - 1) // nshards, mper_lo)
                need_c = ((~hard) & (n_act < compact_lt)
                          & (m_per_new * nshards < m))
            else:
                need_c = jnp.bool_(False)
            return (s, c, segs + 1, min_act, hard | need_c, need_c, n_act)

        carry0 = (state, cache, jnp.int32(0),
                  jnp.int32(jnp.iinfo(jnp.int32).max), jnp.bool_(False),
                  jnp.bool_(False), jnp.int32(0))
        s, c, segs, min_act, _, need_c, n_act = lax.while_loop(
            epoch_cond, epoch_body, carry0)

        if fmt == "ell" and shrink_interval > 0:
            # (p,) surviving extents ride the summary so an ELL compaction
            # needs no extra readback dispatch; computed only when the
            # predicate fired (the cond keeps the scan off the hot exit).
            shard_ext = lax.cond(
                need_c,
                lambda: dataplane.ell_shard_extents_dyn(
                    data.vals, s.active, n_act, nshards),
                lambda: jnp.zeros((nshards,), jnp.int32))
        else:
            shard_ext = jnp.zeros((nshards,), jnp.int32)

        summ = EpochSummary(
            step=s.step, segs=segs, n_active=n_act, min_active=min_act,
            n_shrinks=s.n_shrinks, converged=s.converged,
            stalled=s.stalled, need_compact=need_c,
            cache_hits=c.hits if cached else jnp.int32(0),
            cache_misses=c.misses if cached else jnp.int32(0),
            shard_ext=shard_ext)
        return s, c, summ

    return run_epoch


def init_state(alpha: jax.Array, gamma: jax.Array,
               active: jax.Array) -> SMOState:
    """Fresh chunk state around the given buffer arrays — the single source
    of truth for the non-array scalar fields. Alg. 1 lines 1-3 correspond
    to alpha = 0, gamma = -y on the initial buffer; the driver passes
    whatever the current (possibly compacted/restored) buffer holds.
    Selection scalars are (re)established by the runner before its first
    iteration."""
    return SMOState(
        alpha=alpha,
        gamma=gamma,
        active=active,
        beta_up=jnp.float32(-1.0),
        beta_low=jnp.float32(1.0),
        i_up=jnp.int32(0),
        i_low=jnp.int32(0),
        step=jnp.int32(0),
        next_shrink=jnp.int32(0),
        n_shrinks=jnp.int32(0),
        converged=jnp.bool_(False),
        stalled=jnp.bool_(False),
    )
