"""xLSTM (arXiv:2405.04517): mLSTM (matrix-memory, chunkwise-parallel) and
sLSTM (scalar-memory, recurrent) blocks.

Layout: groups of (slstm_every - 1) mLSTM blocks followed by one sLSTM
block, scanned. d_ff = 0 in the assigned config — per the paper, blocks are
gated up/down projections rather than separate FFNs (mLSTM pf=2, sLSTM
pf=4/3).

The mLSTM trains in a *chunkwise* form (exact, stabilized): within a chunk
the decay matrix D_ij = b_i - b_j + ig_j gives an attention-like (c x c)
contraction; across chunks a (dh x dh) matrix memory C, normalizer n and a
log-space stabilizer m carry state — sub-quadratic in L, which is why this
arch runs the long_500k cell (DESIGN.md §5). Decode is the O(dh^2)
single-step recurrence on (C, n, m).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.api import ModelConfig

_NEG = -1e30


# ----------------------------------------------------------- mLSTM core
def _mlstm_chunk_scan(q, k, v, ig, lf, chunk):
    """q,k,v: (L, dh_k/dh_v); ig/lf: (L,) raw input gate & log-sigmoid forget.
    Returns h: (L, dh_v). Exact chunkwise mLSTM with shared per-chunk
    stabilizer (stabilizers cancel algebraically; see module docstring)."""
    L, dhk = q.shape
    dhv = v.shape[1]
    nc = L // chunk
    scale = dhk ** -0.5
    qc = q.reshape(nc, chunk, dhk)
    kc = k.reshape(nc, chunk, dhk)
    vc = v.reshape(nc, chunk, dhv)
    igc = ig.reshape(nc, chunk)
    lfc = lf.reshape(nc, chunk)
    b = jnp.cumsum(lfc, axis=1)                       # (nc, c)
    total = b[:, -1]                                  # (nc,)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    # D_ij = (b_i - b_j) + ig_j  for j <= i
    D = jnp.where(tri[None], b[:, :, None] - b[:, None, :] + igc[:, None, :],
                  _NEG)                               # (nc, c, c)

    def step(carry, xs):
        C, n, m_prev = carry
        q_c, k_c, v_c, D_c, b_c, ig_c, tot = xs
        inter = m_prev + b_c                          # (c,)
        m_c = jax.lax.stop_gradient(
            jnp.maximum(jnp.max(D_c), jnp.max(inter)))
        S = (q_c @ k_c.T) * scale * jnp.exp(D_c - m_c)        # (c, c)
        w_int = jnp.exp(inter - m_c)[:, None]                 # (c, 1)
        num = S @ v_c + w_int * ((q_c @ C) * scale)
        den = jnp.sum(S, -1) + (w_int[:, 0] * (q_c @ n)) * scale
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_c))
        h = num / den[:, None]
        # state to end-of-chunk
        m_new = jax.lax.stop_gradient(jnp.maximum(
            m_prev + tot, jnp.max(tot - b_c + ig_c)))
        dk = jnp.exp(tot - b_c + ig_c - m_new)[:, None]       # (c, 1)
        decay = jnp.exp(m_prev + tot - m_new)
        C = decay * C + (k_c * dk).T @ v_c
        n = decay * n + jnp.sum(k_c * dk, axis=0)
        return (C, n, m_new), h

    init = (jnp.zeros((dhk, dhv), jnp.float32), jnp.zeros((dhk,), jnp.float32),
            jnp.float32(0.0))
    _, h = jax.lax.scan(step, init, (qc, kc, vc, D, b, igc, total))
    return h.reshape(L, dhv)


def _mlstm_decode_step(C, n, m_prev, q, k, v, ig, lf):
    """Single-token mLSTM recurrence. Shapes: C (dhk, dhv), q/k (dhk,)."""
    scale = q.shape[-1] ** -0.5
    m_new = jnp.maximum(lf + m_prev, ig)
    fp = jnp.exp(lf + m_prev - m_new)
    ip = jnp.exp(ig - m_new)
    C = fp * C + ip * jnp.outer(k, v)
    n = fp * n + ip * k
    num = (q @ C) * scale
    den = jnp.maximum(jnp.abs(jnp.dot(q, n)) * scale, jnp.exp(-m_new))
    return C, n, m_new, num / den


# ---------------------------------------------------------- mLSTM block
def _init_mlstm(cfg: ModelConfig, key):
    d = cfg.d_model
    di = 2 * d                     # pf = 2 up-projection
    H = cfg.n_heads
    dh = di // H
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    return {
        "ln": jnp.ones((d,), dt),
        "w_up": common._normal(ks[0], (d, 2 * di), dt, d ** -0.5),  # x | z
        "wq": common._normal(ks[1], (di, H, dh), dt, di ** -0.5),
        "wk": common._normal(ks[2], (di, H, dh), dt, di ** -0.5),
        "wv": common._normal(ks[3], (di, H, dh), dt, di ** -0.5),
        "w_gates": common._normal(ks[4], (di, 2, H), jnp.float32, di ** -0.5),
        "b_gates": jnp.concatenate([
            jnp.full((1, H), 0.0), jnp.full((1, H), 3.0)]),  # i, f bias
        "ln_h": jnp.ones((di,), dt),
        "w_down": common._normal(ks[5], (di, d), dt, di ** -0.5),
    }


def _mlstm_block(cfg: ModelConfig, p, h):
    B, L, d = h.shape
    H = cfg.n_heads
    x = common.rms_norm(h, p["ln"])
    xz = x @ p["w_up"]
    xm, z = jnp.split(xz, 2, axis=-1)                 # (B, L, di)
    di = xm.shape[-1]
    dh = di // H
    q = jnp.einsum("bld,dhk->bhlk", xm, p["wq"]).astype(jnp.float32)
    k = jnp.einsum("bld,dhk->bhlk", xm, p["wk"]).astype(jnp.float32)
    v = jnp.einsum("bld,dhk->bhlk", xm, p["wv"]).astype(jnp.float32)
    gates = jnp.einsum("bld,dgh->bghl", xm.astype(jnp.float32),
                       p["w_gates"]) + p["b_gates"][None, :, :, None]
    ig = gates[:, 0]                                  # (B, H, L)
    lf = jax.nn.log_sigmoid(gates[:, 1])
    chunk = min(cfg.chunk, L)
    core = jax.vmap(jax.vmap(
        functools.partial(_mlstm_chunk_scan, chunk=chunk)))
    hh = core(q, k, v, ig, lf)                        # (B, H, L, dh)
    hh = hh.transpose(0, 2, 1, 3).reshape(B, L, di).astype(h.dtype)
    hh = common.rms_norm(hh, p["ln_h"]) * jax.nn.silu(z)
    return h + hh @ p["w_down"]


# ---------------------------------------------------------- sLSTM block
def _init_slstm(cfg: ModelConfig, key):
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    ff = max(1, (4 * d) // 3)      # pf = 4/3 post-block MLP
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    return {
        "ln": jnp.ones((d,), dt),
        "wx": common._normal(ks[0], (d, 4, H, dh), jnp.float32, d ** -0.5),
        "r": common._normal(ks[1], (4, H, dh, dh), jnp.float32, dh ** -0.5),
        "bias": jnp.zeros((4, H, dh), jnp.float32)
                 .at[1].set(3.0),  # forget-gate bias
        "ln_h": jnp.ones((d,), dt),
        "w_up": common._normal(ks[2], (d, ff), dt, d ** -0.5),
        "w_gate": common._normal(ks[3], (d, ff), dt, d ** -0.5),
        "w_down": common._normal(ks[4], (ff, d), dt, ff ** -0.5),
    }


def _slstm_scan(p, x, state):
    """x: (B, L, 4, H, dh) preactivations; recurrent over L.
    state: (c, n, hs, m) each (B, H, dh)."""

    def step(carry, xt):                              # xt: (B, 4, H, dh)
        c, n, hs, m = carry
        pre = xt + jnp.einsum("bhk,ghkj->bghj", hs, p["r"]) + p["bias"]
        # gate order: z, f, i, o
        zt = jnp.tanh(pre[:, 0])
        ft = pre[:, 1]
        it = pre[:, 2]
        ot = jax.nn.sigmoid(pre[:, 3])
        m_new = jnp.maximum(jax.nn.log_sigmoid(ft) + m, it)
        fp = jnp.exp(jax.nn.log_sigmoid(ft) + m - m_new)
        ip = jnp.exp(it - m_new)
        c = fp * c + ip * zt
        n = fp * n + ip
        hs = ot * c / jnp.maximum(n, 1e-6)
        return (c, n, hs, m_new), hs

    (c, n, hs, m), hseq = jax.lax.scan(step, state, x.transpose(1, 0, 2, 3, 4))
    return hseq.transpose(1, 0, 2, 3), (c, n, hs, m)


def _slstm_block(cfg: ModelConfig, p, h, state=None):
    B, L, d = h.shape
    H = cfg.n_heads
    dh = d // H
    x = common.rms_norm(h, p["ln"])
    pre = jnp.einsum("bld,dghk->blghk", x.astype(jnp.float32), p["wx"])
    if state is None:
        z = jnp.zeros((B, H, dh), jnp.float32)
        state = (z, z, z, jnp.full((B, H, dh), 0.0))
    hseq, state = _slstm_scan(p, pre, state)          # (B, L, H, dh)
    hh = hseq.reshape(B, L, d).astype(h.dtype)
    hh = common.rms_norm(hh, p["ln_h"])
    h = h + hh
    x2 = jax.nn.silu(h @ p["w_gate"]) * (h @ p["w_up"])
    return h + x2 @ p["w_down"], state


# ------------------------------------------------------------- full model
def _group_struct(cfg: ModelConfig):
    every = cfg.slstm_every or (cfg.n_layers + 1)
    n_groups = cfg.n_layers // every
    n_m_group = every - 1
    tail = cfg.n_layers - n_groups * every
    return n_groups, n_m_group, tail


def init(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.dtype)
    G, M, tail = _group_struct(cfg)
    p = {"ln_f": jnp.ones((cfg.d_model,), dt),
         "embed": common._normal(ks[0], (cfg.vocab_size, cfg.d_model), dt, 1.0),
         "unembed": common._normal(ks[1], (cfg.d_model, cfg.vocab_size), dt,
                                   cfg.d_model ** -0.5)}
    if G:
        p["m_groups"] = jax.vmap(jax.vmap(lambda k: _init_mlstm(cfg, k)))(
            jax.random.split(ks[2], G * M).reshape(G, M, 2))
        p["s_groups"] = jax.vmap(lambda k: _init_slstm(cfg, k))(
            jax.random.split(ks[3], G))
    if tail:
        p["m_tail"] = jax.vmap(lambda k: _init_mlstm(cfg, k))(
            jax.random.split(ks[4], tail))
    return p


def forward(params: dict, cfg: ModelConfig, batch: dict):
    h = common.constrain_batch(
        jnp.take(params["embed"], batch["tokens"], axis=0))
    G, M, tail = _group_struct(cfg)

    mblock = functools.partial(_mlstm_block, cfg)
    if cfg.remat == "full":
        mblock = jax.checkpoint(
            mblock, policy=jax.checkpoint_policies.nothing_saveable)

    def group_body(h, gp):
        mp, sp = gp

        def inner(h, lp):
            return mblock(lp, h), None

        h, _ = common.scan_or_unroll(inner, h, mp, M, cfg.scan_layers)
        h, _ = _slstm_block(cfg, sp, h)
        return h, None

    if G:
        h, _ = common.scan_or_unroll(
            group_body, h, (params["m_groups"], params["s_groups"]),
            G, cfg.scan_layers)
    if tail:
        def inner_t(h, lp):
            return mblock(lp, h), None
        h, _ = common.scan_or_unroll(inner_t, h, params["m_tail"], tail,
                                     cfg.scan_layers)
    h = common.rms_norm(h, params["ln_f"])
    return common.constrain_logits(
        jnp.einsum("bld,dv->blv", h, params["unembed"])), jnp.float32(0.0)


# ----------------------------------------------------------------- decode
def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """Constant-size state: no KV cache — O(1) in max_len (the point of the
    long_500k cell). max_len kept for interface parity."""
    G, M, tail = _group_struct(cfg)
    d = cfg.d_model
    H = cfg.n_heads
    di = 2 * d
    dh_m = di // H
    dh_s = d // H
    f32 = jnp.float32
    cache = {"pos": jnp.zeros((), jnp.int32)}
    if G:
        cache["m_C"] = jnp.zeros((G, M, batch, H, dh_m, dh_m), f32)
        cache["m_n"] = jnp.zeros((G, M, batch, H, dh_m), f32)
        cache["m_m"] = jnp.zeros((G, M, batch, H), f32)
        z = jnp.zeros((G, batch, H, dh_s), f32)
        cache["s_state"] = (z, z, z, z)
    if tail:
        cache["t_C"] = jnp.zeros((tail, batch, H, dh_m, dh_m), f32)
        cache["t_n"] = jnp.zeros((tail, batch, H, dh_m), f32)
        cache["t_m"] = jnp.zeros((tail, batch, H), f32)
    return cache


def _mlstm_decode_block(cfg, p, h, C, n, m):
    """h: (B, 1, d). C: (B, H, dh, dh)."""
    B = h.shape[0]
    H = cfg.n_heads
    x = common.rms_norm(h, p["ln"])
    xz = x @ p["w_up"]
    xm, z = jnp.split(xz, 2, axis=-1)
    di = xm.shape[-1]
    q = jnp.einsum("bld,dhk->bhk", xm, p["wq"]).astype(jnp.float32)
    k = jnp.einsum("bld,dhk->bhk", xm, p["wk"]).astype(jnp.float32)
    v = jnp.einsum("bld,dhk->bhk", xm, p["wv"]).astype(jnp.float32)
    gates = jnp.einsum("bld,dgh->bgh", xm.astype(jnp.float32),
                       p["w_gates"]) + p["b_gates"][None]
    ig = gates[:, 0]
    lf = jax.nn.log_sigmoid(gates[:, 1])
    step = jax.vmap(jax.vmap(_mlstm_decode_step))     # over B, H
    C, n, m, hh = step(C, n, m, q, k, v, ig, lf)
    hh = hh.reshape(B, 1, di).astype(h.dtype)
    hh = common.rms_norm(hh, p["ln_h"]) * jax.nn.silu(z)
    return h + hh @ p["w_down"], C, n, m


def decode(params: dict, cfg: ModelConfig, cache: dict, batch: dict):
    h = jnp.take(params["embed"], batch["tokens"], axis=0)    # (B, 1, d)
    G, M, tail = _group_struct(cfg)
    new = dict(cache)

    if G:
        def group_body(h, xs):
            mp, sp, C, n, m, s_st = xs

            def inner(carry, ys):
                h = carry
                lp, Ci, ni, mi = ys
                h, Ci, ni, mi = _mlstm_decode_block(cfg, lp, h, Ci, ni, mi)
                return h, (Ci, ni, mi)

            h, (C, n, m) = common.scan_or_unroll(inner, h, (mp, C, n, m),
                                                 M, cfg.scan_layers)
            h, s_st = _slstm_block(cfg, sp, h, s_st)
            return h, (C, n, m, s_st)

        h, (Cs, ns, ms, s_states) = common.scan_or_unroll(
            group_body, h,
            (params["m_groups"], params["s_groups"], cache["m_C"],
             cache["m_n"], cache["m_m"], cache["s_state"]),
            G, cfg.scan_layers)
        new.update(m_C=Cs, m_n=ns, m_m=ms, s_state=s_states)
    if tail:
        def inner_t(carry, ys):
            h = carry
            lp, Ci, ni, mi = ys
            h, Ci, ni, mi = _mlstm_decode_block(cfg, lp, h, Ci, ni, mi)
            return h, (Ci, ni, mi)
        h, (C, n, m) = common.scan_or_unroll(
            inner_t, h, (params["m_tail"], cache["t_C"], cache["t_n"],
                         cache["t_m"]), tail, cfg.scan_layers)
        new.update(t_C=C, t_n=n, t_m=m)
    h = common.rms_norm(h, params["ln_f"])
    logits = common.constrain_logits(
        jnp.einsum("bld,dv->blv", h, params["unembed"]))
    new["pos"] = cache["pos"] + 1
    return logits, new
