"""Model zoo public surface: ModelConfig + the family registry.

One frozen config type covers all ten assigned architectures; family
selects the block implementation (transformer / xlstm / zamba2-hybrid).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | vlm | audio | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False         # qwen-style
    rope_theta: float = 5e5
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 1e-2
    moe_impl: str = "scatter"      # 'scatter': gather/scatter dispatch,
                                   # O(T*d) movement; 'einsum': GShard
                                   # one-hot dispatch, O(T*E*cap*d) FLOPs
                                   # (~2x expert compute — §Perf iter 4)
    # frontend: 'tokens' (embedding lookup) or 'embeds' (stub modality
    # frontend supplies precomputed patch/frame embeddings)
    frontend: str = "tokens"
    # ssm / hybrid structure
    ssm_state: int = 0             # mamba2 state size N
    ssm_conv: int = 4
    ssm_head_dim: int = 64         # mamba2 P
    ssm_expand: int = 2
    slstm_every: int = 0           # xLSTM: every k-th block is sLSTM
    attn_every: int = 0            # zamba2: shared attn block every k layers
    chunk: int = 256               # chunkwise scan length (mLSTM/SSD)
    # numerics / execution
    dtype: str = "bfloat16"
    remat: str = "full"            # none | full
    use_flash: bool = False        # Pallas attention in train/prefill
    scan_layers: bool = True       # False: unroll (dry-run needs exact
                                   # cost_analysis; XLA doesn't scale while-
                                   # loop bodies by trip count)
    attn_block_q: int = 512        # blockwise-attention q tile (jnp path)
    seq_parallel: bool = False     # Korthikanti-style L-sharded residual
                                   # stream. MEASURED: ~5% win on prefill,
                                   # 2.5x collective REGRESSION on train
                                   # (constraint transposes in backward) —
                                   # off by default; see §Perf iteration 2.
    layout: str = "tp"             # 'tp': tensor/expert parallel over
                                   # 'model' (baseline); 'fsdp': fold the
                                   # model axis into data parallelism —
                                   # per-layer weight AG replaces the
                                   # per-layer activation AR (4x less link
                                   # traffic for dense train at this size;
                                   # §Perf iteration 6)
    # capability flags
    subquadratic: bool = False     # long_500k eligibility (DESIGN.md §5)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def active_params(self) -> int:
        """~6*N*D convention's N: parameters touched per token."""
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        attn = d * (self.n_heads + 2 * self.n_kv_heads) * self.hd \
            + self.n_heads * self.hd * d
        if self.family == "ssm":
            din = self.ssm_expand * d
            blk = 2 * d * din + din * d + din * self.ssm_state * 2
            return self.n_layers * blk + 2 * V * d
        mlp = 3 * d * ff
        if self.is_moe:
            mlp = mlp * self.top_k + d * self.n_experts
        per_layer = attn + mlp
        if self.family == "hybrid":
            din = self.ssm_expand * d
            per_layer = 2 * d * din + din * d + din * self.ssm_state * 2
            shared = attn + 3 * d * ff
            return self.n_layers * per_layer + shared + 2 * V * d
        return self.n_layers * per_layer + 2 * V * d

    def total_params(self) -> int:
        if not self.is_moe:
            return self.active_params()
        d, ff = self.d_model, self.d_ff
        attn = d * (self.n_heads + 2 * self.n_kv_heads) * self.hd \
            + self.n_heads * self.hd * d
        per_layer = attn + 3 * d * ff * self.n_experts + d * self.n_experts
        return self.n_layers * per_layer + 2 * self.vocab_size * d


def build(cfg: ModelConfig):
    """Returns the family module implementing init/forward/init_cache/decode."""
    if cfg.family == "ssm":
        from repro.models import xlstm
        return xlstm
    if cfg.family == "hybrid":
        from repro.models import zamba
        return zamba
    from repro.models import transformer
    return transformer
