"""Zamba2-style hybrid (arXiv:2411.15242): Mamba2 (SSD) backbone with a
single *shared* full-attention+MLP block invoked every ``attn_every``
layers (shared weights — the Zamba trick that buys attention quality at
~1/6 of the KV/parameter cost).

Mamba2/SSD trains chunkwise (exact): within a chunk, the scalar-decay
kernel L_ij = exp(b_i - b_j) gates a (c x c) C@B^T contraction; across
chunks an (N x P) state per head carries. Decode is the O(N*P) recurrence.
Sub-quadratic overall (the single shared-attention KV cache is the only
L-sized state), so this arch runs long_500k (DESIGN.md §5).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.api import ModelConfig


# ------------------------------------------------------------- SSD core
def _ssd_chunk_scan(xdt, B_, C_, la, chunk):
    """Single head. xdt: (L, P) inputs pre-scaled by dt; B_, C_: (L, N);
    la: (L,) log decay (= dt * A, <= 0). Returns y: (L, P)."""
    L, P = xdt.shape
    N = B_.shape[1]
    nc = L // chunk
    x = xdt.reshape(nc, chunk, P)
    Bc = B_.reshape(nc, chunk, N)
    Cc = C_.reshape(nc, chunk, N)
    lac = la.reshape(nc, chunk)
    b = jnp.cumsum(lac, axis=1)                     # (nc, c)
    tot = b[:, -1]
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    # L_ij = exp(b_i - b_j) for j <= i (decay from j+1..i); diag includes own
    Dmat = jnp.where(tri[None], b[:, :, None] - b[:, None, :], -jnp.inf)

    def step(carry, xs):
        S = carry                                    # (N, P)
        x_c, B_c, C_c, D_c, b_c, t = xs
        G = (C_c @ B_c.T) * jnp.exp(D_c)             # (c, c)
        y = G @ x_c                                  # intra
        y = y + jnp.exp(b_c)[:, None] * (C_c @ S)    # inter
        dk = jnp.exp(t - b_c)[:, None]               # decay j -> chunk end
        S = jnp.exp(t) * S + (B_c * dk).T @ x_c      # (N, P)
        return S, y

    S0 = jnp.zeros((N, P), jnp.float32)
    _, y = jax.lax.scan(step, S0, (x, Bc, Cc, Dmat, b, tot))
    return y.reshape(L, P)


def _causal_conv(x, w, state=None):
    """Depthwise causal conv. x: (B, L, C); w: (K, C). state: (B, K-1, C)."""
    K = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state, x], axis=1)
    out = sum(xp[:, i: i + x.shape[1], :] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1):, :] if K > 1 else None
    return out, new_state


# ------------------------------------------------------------ mamba block
def _init_mamba(cfg: ModelConfig, key):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    N = cfg.ssm_state
    H = di // cfg.ssm_head_dim
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    conv_ch = di + 2 * N
    return {
        "ln": jnp.ones((d,), dt),
        # in_proj -> [z (di) | x (di) | B (N) | C (N) | dt (H)]
        "w_in": common._normal(ks[0], (d, 2 * di + 2 * N + H), dt, d ** -0.5),
        "conv_w": common._normal(ks[1], (cfg.ssm_conv, conv_ch), dt,
                                 cfg.ssm_conv ** -0.5),
        "a_log": jnp.zeros((H,), jnp.float32) + jnp.log(
            jnp.arange(1, H + 1, dtype=jnp.float32)),
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),
        "ln_h": jnp.ones((di,), dt),
        "w_out": common._normal(ks[2], (di, d), dt, di ** -0.5),
    }


def _mamba_split(cfg, p, h):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    N = cfg.ssm_state
    H = di // cfg.ssm_head_dim
    x = common.rms_norm(h, p["ln"])
    zxbcdt = x @ p["w_in"]
    z = zxbcdt[..., :di]
    xin = zxbcdt[..., di: 2 * di]
    Bc = zxbcdt[..., 2 * di: 2 * di + N]
    Cc = zxbcdt[..., 2 * di + N: 2 * di + 2 * N]
    dtr = zxbcdt[..., 2 * di + 2 * N:]
    return z, xin, Bc, Cc, dtr, di, N, H


def _mamba_block(cfg: ModelConfig, p, h, conv_state=None, ssm_state=None,
                 single_step=False):
    B, L, d = h.shape
    z, xin, Bc, Cc, dtr, di, N, H = _mamba_split(cfg, p, h)
    P = cfg.ssm_head_dim
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], conv_state)
    conv_out = jax.nn.silu(conv_out)
    xin = conv_out[..., :di]
    Bc = conv_out[..., di: di + N]
    Cc = conv_out[..., di + N:]

    dt_ = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])   # (B,L,H)
    A = -jnp.exp(p["a_log"])                         # (H,) negative
    la = dt_ * A                                     # (B, L, H) log decay
    xh = xin.astype(jnp.float32).reshape(B, L, H, P)
    xdt = xh * dt_[..., None]

    if single_step:
        # recurrent: S' = exp(la) S + dt * B x^T ; y = C S'
        S = ssm_state                                # (B, H, N, P)
        dec = jnp.exp(la[:, 0])                      # (B, H)
        S = dec[:, :, None, None] * S + jnp.einsum(
            "bn,bhp->bhnp", Bc[:, 0].astype(jnp.float32), xdt[:, 0])
        y = jnp.einsum("bn,bhnp->bhp", Cc[:, 0].astype(jnp.float32), S)
        y = y.reshape(B, 1, di)
        new_ssm = S
    else:
        chunk = min(cfg.chunk, L)
        # vmap over batch (axis 0), then heads (axis 1 of xdt/la; B_, C_
        # are shared across heads)
        core = jax.vmap(jax.vmap(
            functools.partial(_ssd_chunk_scan, chunk=chunk),
            in_axes=(1, None, None, 1), out_axes=1))
        y = core(xdt, Bc.astype(jnp.float32), Cc.astype(jnp.float32), la)
        y = y.reshape(B, L, di)                      # (B, L, H, P) contiguous
        new_ssm = None
    y = y.astype(h.dtype) * jax.nn.silu(z)
    y = common.rms_norm(y, p["ln_h"])
    return h + y @ p["w_out"], new_conv, new_ssm


# ------------------------------------------------- shared attention block
def _init_shared_attn(cfg: ModelConfig, key):
    d, H, Hkv, hd, ff = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                         cfg.d_ff)
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    return {
        "ln1": jnp.ones((d,), dt),
        "ln2": jnp.ones((d,), dt),
        "wq": common._normal(ks[0], (d, H, hd), dt, d ** -0.5),
        "wk": common._normal(ks[1], (d, Hkv, hd), dt, d ** -0.5),
        "wv": common._normal(ks[2], (d, Hkv, hd), dt, d ** -0.5),
        "wo": common._normal(ks[3], (H, hd, d), dt, (H * hd) ** -0.5),
        "w_gate": common._normal(ks[4], (d, ff), dt, d ** -0.5),
        "w_up": common._normal(ks[5], (d, ff), dt, d ** -0.5),
        "w_down": common._normal(ks[6], (ff, d), dt, ff ** -0.5),
    }


def _shared_attn_block(cfg, p, h, positions):
    x = common.rms_norm(h, p["ln1"])
    g = cfg.n_heads // cfg.n_kv_heads
    wk = p["wk"] if g == 1 else jnp.repeat(p["wk"], g, axis=1)
    wv = p["wv"] if g == 1 else jnp.repeat(p["wv"], g, axis=1)
    q = jnp.einsum("bld,dhk->blhk", x, p["wq"])
    k = jnp.einsum("bld,dhk->blhk", x, wk)
    v = jnp.einsum("bld,dhk->blhk", x, wv)
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k = common.apply_rope(k, positions, cfg.rope_theta)
    q = common.constrain_heads(q)
    k = common.constrain_heads(k)
    v = common.constrain_heads(v)
    attn = common.attention(q, k, v, causal=True, use_flash=cfg.use_flash,
                            block_q=cfg.attn_block_q)
    h = h + jnp.einsum("blhk,hkd->bld", attn, p["wo"])
    x = common.rms_norm(h, p["ln2"])
    return h + common.swiglu(x, p["w_gate"], p["w_up"], p["w_down"])


# ------------------------------------------------------------- full model
def _group_struct(cfg: ModelConfig):
    every = cfg.attn_every or (cfg.n_layers + 1)
    G = cfg.n_layers // every
    tail = cfg.n_layers - G * every
    return G, every, tail


def init(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.dtype)
    G, every, tail = _group_struct(cfg)
    p = {"ln_f": jnp.ones((cfg.d_model,), dt),
         "embed": common._normal(ks[0], (cfg.vocab_size, cfg.d_model), dt, 1.0),
         "unembed": common._normal(ks[1], (cfg.d_model, cfg.vocab_size), dt,
                                   cfg.d_model ** -0.5),
         "shared_attn": _init_shared_attn(cfg, ks[2])}
    if G:
        p["groups"] = jax.vmap(jax.vmap(lambda k: _init_mamba(cfg, k)))(
            jax.random.split(ks[3], G * every).reshape(G, every, 2))
    if tail:
        p["tail"] = jax.vmap(lambda k: _init_mamba(cfg, k))(
            jax.random.split(ks[4], tail))
    return p


def forward(params: dict, cfg: ModelConfig, batch: dict):
    h = common.constrain_batch(
        jnp.take(params["embed"], batch["tokens"], axis=0))
    L = h.shape[1]
    positions = jnp.arange(L, dtype=jnp.int32)[None]
    G, every, tail = _group_struct(cfg)

    def mblock(lp, h):
        return _mamba_block(cfg, lp, h)[0]

    if cfg.remat == "full":
        mblock = jax.checkpoint(
            mblock, policy=jax.checkpoint_policies.nothing_saveable)

    def group_body(h, gp):
        def inner(h, lp):
            return mblock(lp, h), None
        h, _ = common.scan_or_unroll(inner, h, gp, every, cfg.scan_layers)
        h = _shared_attn_block(cfg, params["shared_attn"], h, positions)
        return h, None

    if G:
        h, _ = common.scan_or_unroll(group_body, h, params["groups"], G,
                                     cfg.scan_layers)
    if tail:
        def inner_t(h, lp):
            return mblock(lp, h), None
        h, _ = common.scan_or_unroll(inner_t, h, params["tail"], tail,
                                     cfg.scan_layers)
    h = common.rms_norm(h, params["ln_f"])
    return common.constrain_logits(
        jnp.einsum("bld,dv->blv", h, params["unembed"])), jnp.float32(0.0)


# ----------------------------------------------------------------- decode
def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    G, every, tail = _group_struct(cfg)
    di = cfg.ssm_expand * cfg.d_model
    N = cfg.ssm_state
    H = di // cfg.ssm_head_dim
    P = cfg.ssm_head_dim
    K = cfg.ssm_conv
    conv_ch = di + 2 * N
    f32 = jnp.float32
    dt = jnp.dtype(cfg.dtype)
    cache = {"pos": jnp.zeros((), jnp.int32)}
    if G:
        # the ONLY L-sized state: one KV cache per shared-attn invocation
        # (weights are shared; activations — hence caches — are not)
        cache["ak"] = jnp.zeros((G, batch, max_len, cfg.n_kv_heads, cfg.hd), dt)
        cache["av"] = jnp.zeros((G, batch, max_len, cfg.n_kv_heads, cfg.hd), dt)
        cache["g_conv"] = jnp.zeros((G, every, batch, K - 1, conv_ch), dt)
        cache["g_ssm"] = jnp.zeros((G, every, batch, H, N, P), f32)
    if tail:
        cache["t_conv"] = jnp.zeros((tail, batch, K - 1, conv_ch), dt)
        cache["t_ssm"] = jnp.zeros((tail, batch, H, N, P), f32)
    return cache


def _shared_attn_decode(cfg, p, h, ak, av, pos):
    from repro.models import transformer as T
    x = common.rms_norm(h, p["ln1"])
    q = jnp.einsum("bld,dhk->blhk", x, p["wq"])
    k = jnp.einsum("bld,dhk->blhk", x, p["wk"])
    v = jnp.einsum("bld,dhk->blhk", x, p["wv"])
    posv = pos[None, None]
    q = common.apply_rope(q, posv, cfg.rope_theta)
    k = common.apply_rope(k, posv, cfg.rope_theta)
    ak = jax.lax.dynamic_update_slice_in_dim(ak, k, pos, axis=1)
    av = jax.lax.dynamic_update_slice_in_dim(av, v, pos, axis=1)
    attn = T._decode_attention(q, ak, av, pos)
    h = h + jnp.einsum("blhk,hkd->bld", attn, p["wo"])
    x = common.rms_norm(h, p["ln2"])
    return h + common.swiglu(x, p["w_gate"], p["w_up"], p["w_down"]), ak, av


def decode(params: dict, cfg: ModelConfig, cache: dict, batch: dict):
    h = common.constrain_batch(
        jnp.take(params["embed"], batch["tokens"], axis=0))
    pos = cache["pos"]
    G, every, tail = _group_struct(cfg)
    new = dict(cache)

    if G:
        # scan over groups; each group owns its shared-attn KV cache slice
        def group_body(h, xs):
            gp, conv_s, ssm_s, ak_g, av_g = xs

            def inner(h, ys):
                lp, cs, ss = ys
                h, ncs, nss = _mamba_block(cfg, lp, h, cs, ss,
                                           single_step=True)
                return h, (ncs, nss)

            h, (ncs, nss) = common.scan_or_unroll(
                inner, h, (gp, conv_s, ssm_s), every, cfg.scan_layers)
            h, ak_g, av_g = _shared_attn_decode(cfg, params["shared_attn"], h,
                                                ak_g, av_g, pos)
            return h, (ncs, nss, ak_g, av_g)

        h, (gconv, gssm, ak, av) = common.scan_or_unroll(
            group_body, h,
            (params["groups"], cache["g_conv"], cache["g_ssm"],
             cache["ak"], cache["av"]), G, cfg.scan_layers)
        new.update(g_conv=gconv, g_ssm=gssm, ak=ak, av=av)
    if tail:
        def inner_t(h, ys):
            lp, cs, ss = ys
            h, ncs, nss = _mamba_block(cfg, lp, h, cs, ss, single_step=True)
            return h, (ncs, nss)
        h, (tconv, tssm) = common.scan_or_unroll(
            inner_t, h, (params["tail"], cache["t_conv"], cache["t_ssm"]),
            tail, cfg.scan_layers)
        new.update(t_conv=tconv, t_ssm=tssm)
    h = common.rms_norm(h, params["ln_f"])
    logits = common.constrain_logits(
        jnp.einsum("bld,dv->blv", h, params["unembed"]))
    new["pos"] = pos + 1
    return logits, new
