"""Shared building blocks for the model zoo: norms, RoPE, attention (GQA),
gated MLPs, stable cross-entropy, parameter init helpers.

Everything is functional: params are plain nested dicts of jax.Arrays,
models are pure functions — pjit/shard_map handle distribution, and
``jax.eval_shape`` over ``init`` gives the dry-run its ShapeDtypeStructs
without allocating.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

Params = Any


# ---------------------------------------------------------------- init utils
def _normal(key, shape, dtype, scale):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else d_in ** -0.5
    return _normal(key, (d_in, d_out), dtype, scale)


# ------------------------------------------------------------------ norms
def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * weight


# ------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float = 1e4) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, L, H, Dh); positions: (B, L) or (L,)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B?, L, dh/2)
    if ang.ndim == 2:                                   # (L, dh/2)
        ang = ang[None]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# -------------------------------------------------------------- attention
def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                        block_q: int = 512) -> jax.Array:
    """Causal GQA attention scanned over q blocks: peak logits memory is
    (B, bq, H, Lk) instead of (B, Lq, H, Lk) — the jnp-path answer to the
    paper's recompute-over-cache doctrine, and what keeps prefill_32k inside
    HBM without the Pallas kernel. Exact (not an approximation)."""
    B, L, H, Dh = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    scale = Dh ** -0.5
    nb = L // block_q
    qb = q.reshape(B, nb, block_q, H, Dh).transpose(1, 0, 2, 3, 4)
    cols = jnp.arange(L)

    def step(_, xs):
        qi, bi = xs                                     # (B, bq, H, Dh)
        qg = qi.reshape(B, block_q, Hkv, g, Dh)
        # bf16 operands, fp32 accumulation — a f32 cast of K/V would double
        # both HBM traffic and the sharded-collective payloads
        logits = jnp.einsum("bqhgd,bkhd->bqhgk", qg, k,
                            preferred_element_type=jnp.float32) * scale
        rows = bi * block_q + jnp.arange(block_q)
        mask = rows[:, None] >= cols[None, :]
        logits = jnp.where(mask[None, :, None, None, :], logits, -jnp.inf)
        p = jax.nn.softmax(logits, axis=-1)
        o = jnp.einsum("bqhgk,bkhd->bqhgd", p.astype(v.dtype), v,
                       preferred_element_type=jnp.float32)
        return None, o.reshape(B, block_q, H, Dh)

    _, ob = jax.lax.scan(step, None, (qb, jnp.arange(nb)))
    return ob.transpose(1, 0, 2, 3, 4).reshape(B, L, H, Dh).astype(q.dtype)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, use_flash: bool = False,
              block_q: int = 512) -> jax.Array:
    """GQA attention. q: (B, Lq, H, Dh), k/v: (B, Lk, Hkv, Dh)."""
    if use_flash and q.shape[1] > 1:
        from repro.kernels import ops as kops
        o = kops.flash_attention(q.transpose(0, 2, 1, 3),
                                 k.transpose(0, 2, 1, 3),
                                 v.transpose(0, 2, 1, 3), causal)
        return o.transpose(0, 2, 1, 3)
    L = q.shape[1]
    if causal and L == k.shape[1] and L > block_q and L % block_q == 0:
        return blockwise_attention(q, k, v, block_q)
    from repro.kernels import ref
    return ref.mha(q, k, v, causal=causal)


# ------------------------------------------------------------------ MLPs
def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


# --------------------------------------------------- activation sharding
import contextlib as _contextlib

_EXCLUDED_AXES: set = set()


@_contextlib.contextmanager
def exclude_batch_axes(*axes: str):
    """Drop axes from activation sharding constraints — used when an outer
    vmap(spmd_axis_name=...) already owns them (compressed pod-DP path)."""
    global _EXCLUDED_AXES
    old = set(_EXCLUDED_AXES)
    _EXCLUDED_AXES |= set(axes)
    try:
        yield
    finally:
        _EXCLUDED_AXES = old


def _ambient_batch_axes(layout: str = "tp"):
    try:
        m = jax.sharding.get_abstract_mesh()
    except Exception:
        return (), {}
    if m is None or not m.axis_names:
        return (), {}
    sizes = dict(zip(m.axis_names, m.axis_sizes))
    names = ("pod", "data", "model") if layout == "fsdp" else ("pod", "data")
    ba = tuple(a for a in names
               if a in sizes and a not in _EXCLUDED_AXES)
    return ba, sizes


def constrain_batch(x: jax.Array, layout: str = "tp") -> jax.Array:
    """Pin dim 0 to the batch axes ('pod','data'; + 'model' under the fsdp
    layout). GSPMD loses the batch sharding at the embedding gather (both
    operands carry 'data'), which silently un-shards every downstream
    activation — this constraint is the fix (EXPERIMENTS.md §Perf iter 0)."""
    from jax.sharding import PartitionSpec as P
    ba, sizes = _ambient_batch_axes(layout)
    if not ba:
        return x
    n = 1
    for a in ba:
        n *= sizes[a]
    if x.ndim < 1 or x.shape[0] % n != 0:
        return x
    return jax.lax.with_sharding_constraint(
        x, P(ba, *([None] * (x.ndim - 1))))


def constrain_hidden(x: jax.Array, seq_parallel: bool = False,
                     layout: str = "tp") -> jax.Array:
    """Residual stream (B, L, d): batch over ('pod','data') (+ 'model' under
    fsdp); with ``seq_parallel``, L over 'model'."""
    from jax.sharding import PartitionSpec as P
    ba, sizes = _ambient_batch_axes(layout)
    if not sizes:
        return x
    n = 1
    for a in ba:
        n *= sizes[a]
    spec = [None] * x.ndim
    if ba and x.ndim >= 1 and x.shape[0] % n == 0:
        spec[0] = ba
    if seq_parallel and x.ndim == 3 and "model" in sizes             and x.shape[1] % sizes["model"] == 0 and x.shape[1] > 1:
        spec[1] = "model"
    return jax.lax.with_sharding_constraint(x, P(*spec))


def constrain_heads(x: jax.Array, layout: str = "tp") -> jax.Array:
    """(B, L, H, Dh): batch over ('pod','data'), heads over 'model' (tp
    layout) or fully batch-parallel (fsdp layout)."""
    from jax.sharding import PartitionSpec as P
    ba, sizes = _ambient_batch_axes(layout)
    if not sizes:
        return x
    n = 1
    for a in ba:
        n *= sizes[a]
    spec = [None] * x.ndim
    if ba and x.shape[0] % n == 0:
        spec[0] = ba
    if layout != "fsdp" and "model" in sizes             and x.shape[2] % sizes["model"] == 0:
        spec[2] = "model"
    return jax.lax.with_sharding_constraint(x, P(*spec))


def repeat_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """GQA -> MHA by repeating kv heads. Done *before* attention so the full
    head dim shards over 'model' even when Hkv < tp (kv=8 vs tp=16); the
    grouped-einsum formulation would force GSPMD to replicate heads because
    the (Hkv, g) reshape can't carry a 16-way sharding on an 8-dim."""
    Hkv = k.shape[2]
    if Hkv == n_heads:
        return k
    return jnp.repeat(k, n_heads // Hkv, axis=2)


def constrain_logits(x: jax.Array, layout: str = "tp") -> jax.Array:
    """(B, L, V): batch over ('pod','data'), vocab over 'model' (tp) or
    fully batch-sharded (fsdp: logsumexp stays local)."""
    from jax.sharding import PartitionSpec as P
    ba, sizes = _ambient_batch_axes(layout)
    if not ba:
        return x
    n = 1
    for a in ba:
        n *= sizes[a]
    spec = [None] * x.ndim
    if x.shape[0] % n == 0:
        spec[0] = ba
    if layout != "fsdp" and "model" in sizes             and x.shape[-1] % sizes["model"] == 0:
        spec[-1] = "model"
    return jax.lax.with_sharding_constraint(x, P(*spec))


# ------------------------------------------------------------- layer loop
def scan_or_unroll(body, carry, xs, length: int, use_scan: bool):
    """lax.scan when ``use_scan`` (compact HLO, fast compile) else a python
    unroll (exact cost_analysis/collective counts for the dry-run — XLA does
    not scale while-body costs by trip count)."""
    if use_scan:
        return jax.lax.scan(body, carry, xs)
    ys = []
    for i in range(length):
        xi = jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree_util.tree_map(lambda *z: jnp.stack(z), *ys)
    else:
        ys = None
    return carry, ys


# ------------------------------------------------------------------- loss
def cross_entropy(logits: jax.Array, targets: jax.Array,
                  z_loss: float = 1e-4):
    """Stable CE in fp32; targets < 0 are masked. Returns (loss, aux).

    The target logit is extracted with an iota==target masked sum, not
    take_along_axis: a gather along the vocab dim forces GSPMD to all-gather
    the vocab-sharded logits (33 GiB/device at llama-scale); the masked sum
    stays sharded and reduces with one tiny psum."""
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    v = logits.shape[-1]
    onehot = (jax.lax.broadcasted_iota(jnp.int32, lg.shape, lg.ndim - 1)
              == jnp.maximum(targets, 0)[..., None])
    tgt = jnp.sum(jnp.where(onehot, lg, 0.0), axis=-1)
    nll = lse - tgt
    if z_loss:
        nll = nll + z_loss * lse ** 2
    mask = (targets >= 0).astype(jnp.float32)
    n = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / n
    return loss, {"ce": (jnp.where(mask > 0, lse - tgt, 0.0)).sum() / n}
