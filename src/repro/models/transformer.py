"""Decoder-only transformer covering the dense / MoE / VLM / audio archs:
GQA + RoPE + RMSNorm + SwiGLU, optional QKV bias (qwen), optional MoE FFN
(GShard-style capacity dispatch, expert-parallel over the 'model' axis),
optional stub frontend (precomputed embeddings instead of token lookup).

Layers are scanned (compact HLO for 60-layer archs) and optionally remat'd
("no kernel cache" doctrine applied to activations — recompute beats HBM).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.api import ModelConfig


# ----------------------------------------------------------------- params
def _init_layer(cfg: ModelConfig, key) -> dict:
    d, hd, H, Hkv, ff = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 10)
    p = {
        "ln1": jnp.ones((d,), dt),
        "ln2": jnp.ones((d,), dt),
        "wq": common._normal(ks[0], (d, H, hd), dt, d ** -0.5),
        "wk": common._normal(ks[1], (d, Hkv, hd), dt, d ** -0.5),
        "wv": common._normal(ks[2], (d, Hkv, hd), dt, d ** -0.5),
        "wo": common._normal(ks[3], (H, hd, d), dt, (H * hd) ** -0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dt)
        p["bk"] = jnp.zeros((Hkv, hd), dt)
        p["bv"] = jnp.zeros((Hkv, hd), dt)
    if cfg.is_moe:
        E = cfg.n_experts
        p["router"] = common._normal(ks[4], (d, E), jnp.float32, d ** -0.5)
        p["we_gate"] = common._normal(ks[5], (E, d, ff), dt, d ** -0.5)
        p["we_up"] = common._normal(ks[6], (E, d, ff), dt, d ** -0.5)
        p["we_down"] = common._normal(ks[7], (E, ff, d), dt, ff ** -0.5)
    else:
        p["w_gate"] = common._normal(ks[4], (d, ff), dt, d ** -0.5)
        p["w_up"] = common._normal(ks[5], (d, ff), dt, d ** -0.5)
        p["w_down"] = common._normal(ks[6], (ff, d), dt, ff ** -0.5)
    return p


def init(cfg: ModelConfig, key) -> dict:
    kl, ke, ku = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.dtype)
    layers = jax.vmap(lambda k: _init_layer(cfg, k))(
        jax.random.split(kl, cfg.n_layers))
    p = {"layers": layers,
         "ln_f": jnp.ones((cfg.d_model,), dt),
         "unembed": common._normal(ku, (cfg.d_model, cfg.vocab_size), dt,
                                   cfg.d_model ** -0.5)}
    if cfg.frontend == "tokens":
        p["embed"] = common._normal(ke, (cfg.vocab_size, cfg.d_model), dt, 1.0)
    return p


# ------------------------------------------------------------------- MoE
def _route(x, p, cfg):
    """Top-k routing + in-expert positions. Shared by both dispatch impls."""
    B, L, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    cap = max(1, int(cfg.capacity_factor * L * k / E))
    logits = x.astype(jnp.float32) @ p["router"]            # (B, L, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gv, gi = jax.lax.top_k(probs, k)                        # (B, L, k)
    gv = gv / jnp.maximum(gv.sum(-1, keepdims=True), 1e-9)
    onehot = jax.nn.one_hot(gi, E, dtype=jnp.float32)       # (B, L, k, E)
    # position of each (token, slot) within its expert, flattened (L*k) order
    flat = onehot.reshape(B, L * k, E)
    pos = jnp.cumsum(flat, axis=1) - flat                   # (B, L*k, E)
    pos = jnp.sum(pos * flat, -1).reshape(B, L, k).astype(jnp.int32)
    keep = pos < cap
    # load-balance aux (Switch): E * sum_e f_e * P_e
    frac = jnp.mean(onehot[..., 0, :], axis=(0, 1))
    mean_p = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac * mean_p)
    return gi, gv, pos, keep, onehot, cap, aux


def _experts(xin, p):
    """xin: (E, B, cap, d) -> (E, B, cap, d)."""
    h = jax.nn.silu(jnp.einsum("ebcd,edf->ebcf", xin, p["we_gate"])) \
        * jnp.einsum("ebcd,edf->ebcf", xin, p["we_up"])
    return jnp.einsum("ebcf,efd->ebcd", h, p["we_down"])


def _moe_ffn(x: jax.Array, p: dict, cfg: ModelConfig):
    """Capacity-bounded top-k MoE. x: (B, L, d); each sequence is a dispatch
    group (capacity = L*k*cf/E). Two dispatch implementations:

    'einsum' (GShard): one-hot (B,L,E,cap) dispatch/combine matmuls —
        simple, but costs ~E*cap*d MACs per token, rivaling the expert
        FLOPs themselves at 16e/top-1.
    'scatter' (default): scatter-add tokens into (B, E*cap, d) slots and
        gather back — O(tokens * d) data movement, no phantom FLOPs
        (EXPERIMENTS.md §Perf iteration 4).
    """
    B, L, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    gi, gv, pos, keep, onehot, cap, aux = _route(x, p, cfg)

    if cfg.moe_impl == "einsum":
        kf = keep.astype(jnp.float32)
        disp_pos = jax.nn.one_hot(pos, cap, dtype=jnp.float32)
        dmat = jnp.einsum("blke,blkc->blec", onehot * kf[..., None],
                          disp_pos).astype(x.dtype)
        comb = jnp.einsum("blke,blkc,blk->blec", onehot * kf[..., None],
                          disp_pos, gv).astype(x.dtype)
        xin = jnp.einsum("blec,bld->ebcd", dmat, x)
        out_e = _experts(xin, p)
        y = jnp.einsum("blec,ebcd->bld", comb, out_e)
        return y, aux

    # scatter dispatch: slot id within the (E*cap,) expert buffer per group;
    # dropped tokens target a sentinel slot that is sliced away
    slot = jnp.where(keep, gi * cap + pos, E * cap)         # (B, L, k)
    buf = jnp.zeros((B, E * cap + 1, d), x.dtype)
    bidx = jnp.arange(B)[:, None, None]
    buf = buf.at[bidx, slot].add(
        jnp.broadcast_to(x[:, :, None, :], (B, L, k, d)))
    xin = buf[:, :-1].reshape(B, E, cap, d).transpose(1, 0, 2, 3)
    out_e = _experts(xin, p)                                # (E, B, cap, d)
    out_b = out_e.transpose(1, 0, 2, 3).reshape(B, E * cap, d)
    out_b = jnp.concatenate(
        [out_b, jnp.zeros((B, 1, d), x.dtype)], axis=1)     # dropped -> 0
    gathered = out_b[bidx, slot]                            # (B, L, k, d)
    y = jnp.einsum("blkd,blk->bld", gathered, gv.astype(x.dtype))
    return y, aux


# ------------------------------------------------------------------ layer
def _layer(cfg: ModelConfig, p: dict, h: jax.Array, positions: jax.Array):
    x = common.rms_norm(h, p["ln1"])
    # GQA -> MHA repeat on the WEIGHT side: repeating kv heads before the
    # projection keeps K/V head-sharded from birth — repeating activations
    # would all-gather B*L*Hkv*hd per layer across 'model' (the dominant
    # collective in the baseline dry-run; EXPERIMENTS.md §Perf iter 1).
    g = cfg.n_heads // cfg.n_kv_heads
    wk = p["wk"] if g == 1 else jnp.repeat(p["wk"], g, axis=1)
    wv = p["wv"] if g == 1 else jnp.repeat(p["wv"], g, axis=1)
    q = jnp.einsum("bld,dhk->blhk", x, p["wq"])
    kk = jnp.einsum("bld,dhk->blhk", x, wk)
    v = jnp.einsum("bld,dhk->blhk", x, wv)
    if cfg.qkv_bias:
        bk = p["bk"] if g == 1 else jnp.repeat(p["bk"], g, axis=0)
        bv = p["bv"] if g == 1 else jnp.repeat(p["bv"], g, axis=0)
        q, kk, v = q + p["bq"], kk + bk, v + bv
    q = common.apply_rope(q, positions, cfg.rope_theta)
    kk = common.apply_rope(kk, positions, cfg.rope_theta)
    q = common.constrain_heads(q, cfg.layout)
    kk = common.constrain_heads(kk, cfg.layout)
    v = common.constrain_heads(v, cfg.layout)
    attn = common.attention(q, kk, v, causal=True, use_flash=cfg.use_flash,
                            block_q=cfg.attn_block_q)
    h = h + jnp.einsum("blhk,hkd->bld", attn, p["wo"])

    x = common.rms_norm(h, p["ln2"])
    if cfg.is_moe:
        y, aux = _moe_ffn(x, p, cfg)
    else:
        y = common.swiglu(x, p["w_gate"], p["w_up"], p["w_down"])
        aux = jnp.float32(0.0)
    return h + y, aux


def forward(params: dict, cfg: ModelConfig, batch: dict) -> tuple:
    """batch: {'tokens': (B, L)} or {'embeds': (B, L, d)}. Returns
    (logits (B, L, V), aux_loss scalar)."""
    if cfg.frontend == "tokens":
        h = jnp.take(params["embed"], batch["tokens"], axis=0)
        L = batch["tokens"].shape[1]
    else:
        h = batch["embeds"].astype(jnp.dtype(cfg.dtype))
        L = h.shape[1]
    h = common.constrain_hidden(h, cfg.seq_parallel, cfg.layout)
    positions = jnp.arange(L, dtype=jnp.int32)[None]

    layer_fn = functools.partial(_layer, cfg)
    if cfg.remat == "full":
        layer_fn = jax.checkpoint(
            layer_fn, policy=jax.checkpoint_policies.nothing_saveable)

    def scan_body(carry, lp):
        h, aux = carry
        h, a = layer_fn(lp, h, positions)
        return (common.constrain_hidden(h, cfg.seq_parallel,
                                        cfg.layout), aux + a), None

    (h, aux), _ = common.scan_or_unroll(
        scan_body, (h, jnp.float32(0.0)), params["layers"],
        cfg.n_layers, cfg.scan_layers)
    h = common.rms_norm(h, params["ln_f"])
    logits = common.constrain_logits(
        jnp.einsum("bld,dv->blv", h, params["unembed"]), cfg.layout)
    return logits, aux


# ----------------------------------------------------------------- decode
def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    dt = jnp.dtype(cfg.dtype)
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt),
            "pos": jnp.zeros((), jnp.int32)}


def _decode_attention(q, kc, vc, pos):
    """q: (B, 1, H, hd); kc/vc: (B, L, Hkv, hd); mask keys > pos."""
    B, L, Hkv, hd = kc.shape
    H = q.shape[2]
    g = H // Hkv
    qg = q.reshape(B, 1, Hkv, g, hd)
    # bf16 cache operands with fp32 accumulation: casting the 32k-deep
    # cache to f32 would materialize a 2x copy per layer per step
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kc,
                        preferred_element_type=jnp.float32)
    logits *= hd ** -0.5
    mask = jnp.arange(L)[None, None, None, None, :] <= pos
    logits = jnp.where(mask, logits, -jnp.inf)
    pr = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", pr.astype(vc.dtype), vc,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def _decode_layer(cfg: ModelConfig, p: dict, kc, vc, h, pos):
    x = common.rms_norm(h, p["ln1"])
    q = jnp.einsum("bld,dhk->blhk", x, p["wq"])
    kk = jnp.einsum("bld,dhk->blhk", x, p["wk"])
    v = jnp.einsum("bld,dhk->blhk", x, p["wv"])
    if cfg.qkv_bias:
        q, kk, v = q + p["bq"], kk + p["bk"], v + p["bv"]
    posv = pos[None, None]                       # (1,1) broadcast positions
    q = common.apply_rope(q, posv, cfg.rope_theta)
    kk = common.apply_rope(kk, posv, cfg.rope_theta)
    kc = jax.lax.dynamic_update_slice_in_dim(kc, kk, pos, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(vc, v, pos, axis=1)
    attn = _decode_attention(q, kc, vc, pos)
    h = h + jnp.einsum("blhk,hkd->bld", attn, p["wo"])
    x = common.rms_norm(h, p["ln2"])
    if cfg.is_moe:
        y, _ = _moe_ffn(x, p, cfg)
    else:
        y = common.swiglu(x, p["w_gate"], p["w_up"], p["w_down"])
    return kc, vc, h + y


def decode(params: dict, cfg: ModelConfig, cache: dict, batch: dict):
    """One decode step. batch: {'tokens': (B, 1)} or {'embeds': (B, 1, d)}.
    Returns (logits (B, 1, V), new cache)."""
    if cfg.frontend == "tokens":
        h = jnp.take(params["embed"], batch["tokens"], axis=0)
    else:
        h = batch["embeds"].astype(jnp.dtype(cfg.dtype))
    h = common.constrain_batch(h, cfg.layout)
    pos = cache["pos"]

    def scan_body(h, xs):
        lp, kc, vc = xs
        kc, vc, h = _decode_layer(cfg, lp, kc, vc, h, pos)
        return h, (kc, vc)

    h, (kcs, vcs) = common.scan_or_unroll(
        scan_body, h, (params["layers"], cache["k"], cache["v"]),
        cfg.n_layers, cfg.scan_layers)
    h = common.rms_norm(h, params["ln_f"])
    logits = common.constrain_logits(
        jnp.einsum("bld,dv->blv", h, params["unembed"]), cfg.layout)
    return logits, {"k": kcs, "v": vcs, "pos": pos + 1}
