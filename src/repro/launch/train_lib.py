"""Step builders: jit-compiled, sharding-annotated train / prefill / decode
steps for every architecture. These are what the examples run and what the
multi-pod dry-run lowers.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import common
from repro.models.api import ModelConfig, build
from repro.optim import adamw, compress
from repro.launch import sharding as shd
from repro.launch.mesh import batch_axes


# ----------------------------------------------------------------- train
def make_loss_fn(cfg: ModelConfig):
    model = build(cfg)

    def loss_fn(params, batch):
        logits, aux = model.forward(params, cfg, batch)
        loss, metrics = common.cross_entropy(logits, batch["targets"])
        if cfg.is_moe:
            loss = loss + cfg.router_aux_weight * aux
            metrics = dict(metrics, router_aux=aux)
        return loss, metrics

    return loss_fn


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                    mesh, grad_compress: Optional[str] = None,
                    accum_steps: int = 1, gather_params_once: bool = False):
    """Returns train_step(params, opt, batch) -> (params, opt, metrics).

    ``accum_steps`` > 1 runs microbatch gradient accumulation (lax.scan over
    the leading batch split): activation memory scales 1/accum while the
    optimizer still sees the full global batch — the overlap knob for big
    global batches (DESIGN.md §6). ``grad_compress`` ('bf16'|'int8')
    compresses the cross-pod gradient all-reduce (multi-pod meshes only)."""
    loss_fn = make_loss_fn(cfg)
    use_pod = grad_compress and "pod" in mesh.axis_names

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    if use_pod:
        # Compressed cross-pod gradient reduction, expressed in pure
        # auto-sharding: vmap the per-pod grad computation over a leading
        # pod-sharded axis (spmd_axis_name pins it to 'pod'), quantize each
        # pod's gradient slice (with error feedback), then mean over the
        # stacked axis — XLA lowers that mean to the cross-pod all-reduce,
        # whose operands are the compressed (dequantized bf16/int8-grid)
        # values. See optim/compress.py for the codec semantics.
        npod = dict(zip(mesh.axis_names, mesh.devices.shape))["pod"]

        def g1(params, b):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, b)
            return loss, metrics, grads

        def compute_grads(params, batch, residuals):
            micro = jax.tree.map(
                lambda x: x.reshape((npod, x.shape[0] // npod)
                                    + x.shape[1:]), batch)
            with common.exclude_batch_axes("pod"):
                loss_s, metrics_s, grads_s = jax.vmap(
                    g1, in_axes=(None, 0), spmd_axis_name="pod")(params,
                                                                 micro)
            if residuals is None:
                residuals = jax.tree.map(
                    lambda g: jnp.zeros(g.shape, jnp.float32), grads_s)

            def codec(g, e):
                x = g.astype(jnp.float32) + e                 # (npod, ...)
                if grad_compress == "int8":
                    amax = jnp.max(jnp.abs(x), axis=tuple(range(1, x.ndim)),
                                   keepdims=True)
                    scale = jnp.maximum(amax, 1e-12) / 127.0
                    deq = jnp.clip(jnp.round(x / scale), -127, 127) * scale
                else:                                          # bf16
                    deq = x.astype(jnp.bfloat16).astype(jnp.float32)
                return deq, x - deq

            flat_g, tdef = jax.tree.flatten(grads_s)
            flat_e = tdef.flatten_up_to(residuals)
            pairs = [codec(g, e) for g, e in zip(flat_g, flat_e)]
            grads = tdef.unflatten([p[0].mean(axis=0) for p in pairs])
            residuals = tdef.unflatten([p[1] for p in pairs])
            metrics = jax.tree.map(lambda m: m.mean(), metrics_s)
            return loss_s.mean(), metrics, grads, residuals
    else:
        def compute_grads(params, batch, residuals):
            loss, metrics, grads = grads_of(params, batch)
            return loss, metrics, grads, residuals

    gspecs = None
    if gather_params_once:
        p_shapes = jax.eval_shape(
            functools.partial(build(cfg).init, cfg), jax.random.PRNGKey(0))
        gspecs = shd.strip_fsdp(shd.param_specs(p_shapes, mesh))

    def train_step(params, opt_state, batch, residuals=None):
        if gather_params_once:
            # ZeRO gather hoisted out of the microbatch loop: one AG per
            # step instead of one per microbatch; the constraint transpose
            # reduce-scatters grads back to the FSDP layout for the update.
            params = jax.tree.map(
                lambda x, sp: jax.lax.with_sharding_constraint(x, sp),
                params, gspecs)
        if accum_steps > 1:
            micro = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]), batch)

            def mb(carry, b):
                gsum, lsum = carry
                loss, metrics, grads, _ = compute_grads(params, b, None)
                gsum = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), gsum, grads)
                return (gsum, lsum + loss), metrics

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (gsum, lsum), ms = jax.lax.scan(
                mb, (g0, jnp.float32(0.0)), micro)
            grads = jax.tree.map(lambda g: g / accum_steps, gsum)
            loss = lsum / accum_steps
            metrics = jax.tree.map(lambda m: m[-1], ms)
        else:
            loss, metrics, grads, residuals = compute_grads(
                params, batch, residuals)
        params, opt_state, om = adamw.update(opt_cfg, grads, opt_state,
                                             params)
        metrics = dict(metrics, loss=loss, **om)
        out = (params, opt_state, metrics)
        return out + ((residuals,) if use_pod else ())

    return train_step


def shardings_for(cfg: ModelConfig, mesh, batch_shapes: dict,
                  gathered_params: bool = False):
    """(param_shardings, opt_shardings, batch_shardings) NamedSharding trees
    derived from eval_shape — no allocation. ``gathered_params`` strips the
    FSDP axes (cost-tier measurement of gather-params-once)."""
    model = build(cfg)
    p_shapes = jax.eval_shape(
        functools.partial(model.init, cfg), jax.random.PRNGKey(0))
    o_shapes = jax.eval_shape(adamw.init, p_shapes)
    p_specs = shd.param_specs(p_shapes, mesh, cfg.layout)
    if gathered_params:
        p_specs = shd.strip_fsdp(p_specs)
    o_specs = {"m": p_specs, "v": p_specs, "step": P()}
    b_specs = shd.batch_specs(batch_shapes, mesh, cfg.layout)
    mk = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda x: isinstance(x, P))
    return mk(p_specs), mk(o_specs), mk(b_specs), (p_shapes, o_shapes)


# ----------------------------------------------------------------- serve
def make_prefill_step(cfg: ModelConfig):
    """Prefill: forward over the prompt; returns last-position logits (the
    next-token distribution). Transformer archs additionally fill a KV cache
    during real serving; SSM/hybrid archs build state by chunked decode
    (DESIGN.md §5 notes)."""
    model = build(cfg)

    def prefill_step(params, batch):
        logits, _ = model.forward(params, cfg, batch)
        return jnp.argmax(logits[:, -1, :], axis=-1)

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """One greedy decode step against the cache."""
    model = build(cfg)

    def serve_step(params, cache, batch):
        logits, cache = model.decode(params, cfg, cache, batch)
        return jnp.argmax(logits[:, -1, :], axis=-1), cache

    return serve_step


def serve_shardings(cfg: ModelConfig, mesh, batch: int, max_len: int):
    model = build(cfg)
    c_shapes = jax.eval_shape(lambda: model.init_cache(cfg, batch, max_len))
    c_specs = shd.cache_specs(c_shapes, mesh)
    mk = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda x: isinstance(x, P))
    return mk(c_specs), c_shapes
