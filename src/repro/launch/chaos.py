"""Fault injection for the SVM epoch cycle — the chaos harness.

The epoch driver (``repro.core.driver``) calls two process-local hooks at
its two fault boundaries:

  * ``on_dispatch(i)``   immediately BEFORE fused-epoch dispatch #i
                         (0-based) is launched;
  * ``on_save(k)``       immediately BEFORE checkpoint save #k (0-based)
                         is written.

Both are no-ops (one attribute read) unless a :class:`FaultPlan` is
installed, so the production hot loop pays nothing. An installed plan can

  * KILL the fit at a chosen dispatch or save boundary (raises
    :class:`InjectedKill` — the process-crash stand-in the chaos tests
    catch or let the subprocess die on);
  * DELAY chosen dispatches by a fixed sleep (a straggling shard, as seen
    from the host: the dispatch wall time inflates, which is exactly the
    signal ``launch.elastic.StragglerWatchdog`` watches).

Because the driver's save boundary IS its dispatch boundary (see the
recovery-path diagram in ``repro.core.driver``), killing at either
boundary leaves only complete, checksummed step directories behind —
resume picks up the newest one and replays the identical trajectory.

On-disk corruption is injected separately (no hook needed — it models a
fault AFTER the fit died): :func:`corrupt_step` truncates or bit-flips a
step's group file, or tears its manifest, and the checkpoint layer's
``complete_steps`` walk must skip it.

CLI: ``--chaos kill@3`` / ``kill-save@2`` / ``delay@5:0.25`` on
``repro.launch.svm_train`` (see :func:`parse_spec`).
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import time
from typing import Optional


class InjectedKill(RuntimeError):
    """The injected process death. Raised from a fault boundary; tests
    either catch it (in-process chaos) or let the subprocess exit."""


@dataclasses.dataclass
class FaultPlan:
    """What to inject, keyed by 0-based boundary counters."""
    kill_at_dispatch: Optional[int] = None   # raise before dispatch #i
    kill_at_save: Optional[int] = None       # raise before save #k
    delay_dispatch: Optional[int] = None     # sleep before dispatch #i ...
    delay_seconds: float = 0.0               # ... for this long
    delay_every: bool = False                # delay EVERY dispatch >= index

    dispatches: int = 0                      # boundary counters (observed)
    saves: int = 0


_PLAN: Optional[FaultPlan] = None


def install(plan: Optional[FaultPlan]) -> None:
    """Install (or, with None, clear) the process-local fault plan."""
    global _PLAN
    _PLAN = plan


@contextlib.contextmanager
def inject(plan: FaultPlan):
    """Scoped install: ``with chaos.inject(FaultPlan(kill_at_dispatch=3)):``"""
    install(plan)
    try:
        yield plan
    finally:
        install(None)


def on_dispatch(i: int) -> None:
    """Driver hook: called before fused-epoch dispatch #i launches."""
    p = _PLAN
    if p is None:
        return
    p.dispatches = i + 1
    if p.delay_dispatch is not None and (
            i == p.delay_dispatch
            or (p.delay_every and i >= p.delay_dispatch)):
        time.sleep(p.delay_seconds)
    if p.kill_at_dispatch is not None and i >= p.kill_at_dispatch:
        raise InjectedKill(f"injected kill at dispatch {i}")


def on_save(k: int) -> None:
    """Driver hook: called before checkpoint save #k is written."""
    p = _PLAN
    if p is None:
        return
    p.saves = k + 1
    if p.kill_at_save is not None and k >= p.kill_at_save:
        raise InjectedKill(f"injected kill at save {k}")


# -- on-disk corruption (post-mortem faults) --------------------------------
def truncate_file(path: str, keep: int = 64) -> None:
    """Truncate ``path`` to its first ``keep`` bytes (a torn write)."""
    with open(path, "r+b") as f:
        f.truncate(keep)


def flip_byte(path: str, offset: int = -1) -> None:
    """XOR one byte of ``path`` (silent media corruption). ``offset`` may
    be negative (from the end); default flips the last byte."""
    size = os.path.getsize(path)
    pos = offset % size
    with open(path, "r+b") as f:
        f.seek(pos)
        b = f.read(1)
        f.seek(pos)
        f.write(bytes([b[0] ^ 0xFF]))


def corrupt_step(ckpt_dir: str, step: Optional[int] = None,
                 mode: str = "truncate") -> str:
    """Corrupt ONE step directory under ``ckpt_dir`` (default: the
    newest): 'truncate' / 'flip' hit the first group .npz, 'manifest'
    tears the manifest itself. Returns the corrupted step dir."""
    from repro.ckpt import checkpoint as ck
    if step is None:
        step = ck.latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step}")
    if mode == "manifest":
        truncate_file(os.path.join(d, "manifest.json"), keep=8)
        return d
    man = ck.load_manifest(d)
    fn = os.path.join(d, next(iter(man["groups"].values()))["file"])
    if mode == "truncate":
        truncate_file(fn)
    elif mode == "flip":
        flip_byte(fn)
    else:
        raise ValueError(f"unknown corruption mode {mode!r} "
                         "(want 'truncate' | 'flip' | 'manifest')")
    return d


# -- CLI spec ----------------------------------------------------------------
def parse_spec(spec: str) -> FaultPlan:
    """Parse a ``--chaos`` spec:

      kill@I          kill before dispatch I
      kill-save@K     kill before checkpoint save K
      delay@I:S       sleep S seconds before dispatch I
      delay-all@I:S   sleep S seconds before every dispatch >= I
    """
    kind, _, rest = spec.partition("@")
    if not rest:
        raise ValueError(f"bad --chaos spec {spec!r} (want KIND@N[:SECS])")
    if kind == "kill":
        return FaultPlan(kill_at_dispatch=int(rest))
    if kind == "kill-save":
        return FaultPlan(kill_at_save=int(rest))
    if kind in ("delay", "delay-all"):
        idx, _, secs = rest.partition(":")
        return FaultPlan(delay_dispatch=int(idx),
                         delay_seconds=float(secs or 0.1),
                         delay_every=kind == "delay-all")
    raise ValueError(f"unknown --chaos kind {kind!r} "
                     "(want kill | kill-save | delay | delay-all)")
