"""Sharding rules: logical parameter/activation layouts -> PartitionSpecs.

MaxText-style rule table with *divisibility-aware fallbacks*: each parameter
leaf (matched by its tree-path suffix) carries an ordered list of candidate
specs over its trailing dims; the first candidate whose named axes divide the
corresponding dims wins. This is what lets one rule set serve all ten
architectures (e.g. yi-34b's 56 heads don't divide the 16-way model axis, so
attention falls back to sharding head_dim=128, which does).

Conventions:
  'model'  tensor/expert parallel axis
  'data'   FSDP axis for parameters & optimizer moments (intra-pod);
           multi-pod keeps params replicated across 'pod' (gradient psum
           crosses DCI once per step, param all-gathers stay on ICI)
  batch    activations shard over ('pod','data') combined
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import batch_axes

# (regex on the dot-joined tree path, [candidate trailing-dim specs])
_PARAM_RULES: list[tuple[str, list[tuple]]] = [
    (r"\bembed$",        [("model", "data"), (None, "data"), (None, None)]),
    (r"\bunembed$",      [("data", "model"), (None, "model"), (None, None)]),
    (r"\bw[qkv]$",       [("data", "model", None), ("data", None, "model"),
                          (None, "model", None), (None, None, "model"),
                          (None, None, None)]),
    (r"\bwo$",           [("model", None, "data"), (None, "model", "data"),
                          (None, None, "data"), (None, None, None)]),
    (r"\bw_(gate|up)$",  [("data", "model"), (None, "model"), (None, None)]),
    (r"\bw_down$",       [("model", "data"), ("model", None), (None, None)]),
    (r"\bwe_(gate|up)$", [("model", "data", None), (None, "data", None),
                          (None, None, None)]),
    (r"\bwe_down$",      [("model", None, "data"), (None, None, "data"),
                          (None, None, None)]),
    (r"\brouter$",       [(None, None)]),
    (r"\bb[qkv]$",       [("model", None), (None, "model"), (None, None)]),
    # xLSTM
    (r"\bw_gates$",      [(None, None, None)]),
    (r"\br$",            [(None, "model", None, None),
                          (None, None, None, None)]),
    (r"\bwx$",           [("data", None, "model", None),
                          ("data", None, None, "model"),
                          (None, None, None, None)]),
    # mamba / zamba
    (r"\bw_in$",         [("data", "model"), (None, "model"), (None, None)]),
    (r"\bw_out$",        [("model", "data"), ("model", None), (None, None)]),
    (r"\bconv_w$",       [(None, "model"), (None, None)]),
    (r"\b(a_log|dt_bias|bias|b_gates)$", [("model",), (None,)]),
    (r"\bln", [(None,)]),
]

_CACHE_RULES: list[tuple[str, list[tuple]]] = [
    # transformer KV cache: (layers, B, S, Hkv, hd)
    (r"\b[kv]$", [("batch", None, "model", None), ("batch", None, None, "model"),
                  (None, None, "model", None), (None, None, None, "model"),
                  (None, None, None, None)]),
    # zamba shared-attn caches: (G, B, S, Hkv, hd) — B may be 1 (long_500k):
    # fall back to sharding the sequence dim (contraction dim -> psum)
    (r"\ba[kv]$", [("batch", None, "model", None),
                   (None, "batch", "model", None),
                   (None, "batch", None, "model"),
                   (None, None, None, None)]),
    # xlstm mLSTM matrix memory: (..., B, H, dh, dh)
    (r"\b(m_C|t_C)$", [("batch", "model", None, None),
                       ("batch", None, "model", None),
                       (None, "model", None, None), (None,) * 4]),
    (r"\b(m_n|t_n)$", [("batch", "model", None), (None, "model", None),
                       (None, None, None)]),
    (r"\b(m_m|t_m)$", [("batch", "model"), (None, "model"), (None, None)]),
    (r"\bs_state",    [("batch", "model", None), (None, "model", None),
                       (None, None, None)]),
    # mamba states: conv (..., B, K-1, C), ssm (..., B, H, N, P)
    (r"\b(g_conv|t_conv)$", [("batch", None, "model"), (None, None, "model"),
                             (None, None, None)]),
    (r"\b(g_ssm|t_ssm)$", [("batch", "model", None, None),
                           (None, "model", None, None), (None,) * 4]),
    (r"\bpos$",       [()]),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
    return ".".join(parts)


def _spec_for(path_s: str, shape: tuple, mesh: Mesh, rules, batch_ax) -> P:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def ax_size(name) -> int:
        if name == "batch":
            return int(np.prod([sizes[a] for a in batch_ax])) if batch_ax else 1
        return sizes.get(name, 0)

    def resolve(name):
        return batch_ax if name == "batch" else name

    for pat, candidates in rules:
        if re.search(pat, path_s):
            for cand in candidates:
                if len(cand) > len(shape):
                    continue
                dims = shape[len(shape) - len(cand):]
                ok = all(a is None or (ax_size(a) and dim % ax_size(a) == 0)
                         for a, dim in zip(cand, dims))
                if ok:
                    full = (None,) * (len(shape) - len(cand)) + tuple(
                        resolve(a) for a in cand)
                    return P(*full)
            return P()
    # default: replicate (scalars, counters)
    return P()


def param_specs(shape_tree: Any, mesh: Mesh, layout: str = "tp"):
    """PartitionSpec pytree for a parameter (or optimizer-state) tree.

    layout='fsdp': the model axis joins data parallelism — every parameter
    shards its first divisible dim over the combined ('data','model') axes
    (pure ZeRO-3; no tensor parallelism)."""
    ba = batch_axes(mesh)
    if layout == "fsdp":
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        fs = tuple(a for a in ("data", "model") if a in sizes)
        nfs = int(np.prod([sizes[a] for a in fs])) if fs else 1

        def f(path, leaf):
            shape = tuple(leaf.shape)
            # largest-first: prefer sharding the biggest divisible dim
            order = sorted(range(len(shape)), key=lambda i: -shape[i])
            for i in order:
                if shape[i] % nfs == 0 and shape[i] >= nfs:
                    spec = [None] * len(shape)
                    spec[i] = fs
                    return P(*spec)
            return P()

        return jax.tree_util.tree_map_with_path(f, shape_tree)

    def f(path, leaf):
        return _spec_for(_path_str(path), tuple(leaf.shape), mesh,
                         _PARAM_RULES, ba)

    return jax.tree_util.tree_map_with_path(f, shape_tree)


def strip_fsdp(spec_tree: Any):
    """Remove 'data'/'pod' (FSDP) axes from parameter specs -> the
    gathered-weights layout used by gather-params-once-per-step (ZeRO
    gathering hoisted out of the microbatch loop; §Perf iteration 5)."""
    def strip(spec):
        def keep(a):
            if a is None:
                return None
            if isinstance(a, (tuple, list)):
                kept = tuple(x for x in a if x not in ("data", "pod"))
                return kept if kept else None
            return None if a in ("data", "pod") else a
        return P(*[keep(a) for a in spec])

    return jax.tree_util.tree_map(strip, spec_tree,
                                  is_leaf=lambda x: isinstance(x, P))


def cache_specs(shape_tree: Any, mesh: Mesh):
    ba = batch_axes(mesh)

    def f(path, leaf):
        return _spec_for(_path_str(path), tuple(leaf.shape), mesh,
                         _CACHE_RULES, ba)

    return jax.tree_util.tree_map_with_path(f, shape_tree)


def batch_specs(batch_tree: Any, mesh: Mesh, layout: str = "tp"):
    """Token/embedding batches: shard dim 0 over the batch axes when it
    divides, else replicate (long_500k's batch=1)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if layout == "fsdp":
        ba = tuple(a for a in ("pod", "data", "model") if a in sizes)
    else:
        ba = batch_axes(mesh)
    n = int(np.prod([dict(zip(mesh.axis_names, mesh.devices.shape))[a]
                     for a in ba])) if ba else 1

    def f(leaf):
        if leaf.ndim >= 1 and n and leaf.shape[0] % n == 0:
            return P(ba, *([None] * (leaf.ndim - 1)))
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map(f, batch_tree)


def logits_spec(mesh: Mesh) -> P:
    return P(batch_axes(mesh), None, "model")


def named(tree, mesh: Mesh, specs):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs)
