"""LM training launcher: ``python -m repro.launch.train --arch <id> ...``.

Thin CLI over the same machinery as examples/train_lm.py (step builders in
train_lib, checkpointing in ckpt, deterministic data in data.tokens). On a
real multi-host TPU deployment this module is the per-host entry point
(jax.distributed.initialize + make_production_mesh instead of a host mesh).
"""
import os
import runpy
import sys

if __name__ == "__main__":
    sys.argv[0] = "train.py"
    runpy.run_path(os.path.join(os.path.dirname(__file__), "..", "..", "..",
                                "examples", "train_lm.py"),
                   run_name="__main__")
