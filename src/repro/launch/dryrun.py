import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, print memory/cost analyses, and extract the roofline
terms (EXPERIMENTS.md §Dry-run / §Roofline).

The two lines above MUST stay first — jax locks the device count at first
init. Nothing else (conftest, benchmarks, smoke tests) sets this flag.

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
"""
import argparse        # noqa: E402
import functools       # noqa: E402
import json            # noqa: E402
import time            # noqa: E402
import traceback       # noqa: E402

import jax             # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import configs                     # noqa: E402
from repro.configs.shapes import SHAPES       # noqa: E402
from repro.launch import roofline, sharding as shd, train_lib  # noqa: E402
from repro.launch.mesh import make_production_mesh             # noqa: E402
from repro.models.api import build            # noqa: E402
from repro.optim import adamw                 # noqa: E402


def _named(tree, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def _tok_out_sharding(mesh, batch: int):
    """Next-token output: batch-sharded when divisible, replicated else
    (long_500k has global_batch=1)."""
    import numpy as np
    ba = shd.batch_axes(mesh)
    n = int(np.prod([dict(zip(mesh.axis_names, mesh.devices.shape))[a]
                     for a in ba])) if ba else 1
    if ba and batch % n == 0:
        return NamedSharding(mesh, P(ba))
    return NamedSharding(mesh, P())


def _layout_for(cfg, shape) -> str:
    """Auto layout per cell (§Perf iteration 6): pure-FSDP (model axis folded
    into data parallelism) wins 3.8x on collectives for dense train cells
    whose global batch covers the whole mesh and whose activations fit at
    accum=1; TP/EP otherwise (MoE dispatch + wide-arch memory)."""
    if (shape.kind == "train" and not cfg.is_moe
            and cfg.family in ("dense", "audio", "vlm")
            and cfg.d_model <= 4096 and shape.global_batch >= 256):
        return "fsdp"
    return cfg.layout


def _moe_impl_for(cfg, shape) -> str:
    """Per-shape MoE dispatch policy (§Perf known-regression fix): scatter
    wins on train/decode; at 32k-token prefill groups the scatter/gather
    resharding outweighs the phantom-FLOP savings — use the GShard einsum
    there."""
    return "einsum" if shape.kind == "prefill" else cfg.moe_impl


def _accum_for(cfg, shape) -> int:
    """Microbatch accumulation factor for train cells (memory knob).
    Wide archs (d_model >= 5120) need 16 to fit 16 GiB v5e HBM at global
    batch 256 x 4k; the fsdp layout requires accum=1 (microbatch must cover
    the full 256-device combined axis)."""
    if shape.kind != "train":
        return 1
    if _layout_for(cfg, shape) == "fsdp":
        return 1
    # microbatch must stay divisible by the 16-way data axis (256/16): a
    # smaller microbatch un-shards the batch dim and replicates activations
    return 16 if cfg.d_model >= 5120 else 8


def lower_cell(arch: str, shape_name: str, mesh, verbose: bool = True,
               overrides: dict | None = None, tier: str = "full"):
    """Lower + compile one (arch x shape x mesh) cell.

    tier='full':  the deliverable artifact — full global batch, scanned
                  layers, microbatch accumulation on train shapes. Proves
                  the sharding compiles and gives memory_analysis().
    tier='cost':  unrolled layers on one microbatch — exact cost_analysis
                  and collective counts (XLA doesn't scale while-loop bodies
                  by trip count); the caller scales by accum_steps.
    Returns (lowered, compiled, model_flops, chips, accum).
    """
    import dataclasses
    cfg = dataclasses.replace(configs.full_config(arch),
                              scan_layers=(tier == "full"),
                              **(overrides or {}))
    shape = SHAPES[shape_name]
    if "layout" not in (overrides or {}):
        cfg = dataclasses.replace(cfg, layout=_layout_for(cfg, shape))
    if cfg.is_moe and "moe_impl" not in (overrides or {}):
        cfg = dataclasses.replace(cfg, moe_impl=_moe_impl_for(cfg, shape))
    ok, why = configs.applicable(cfg, shape)
    if not ok:
        raise SkipCell(why)
    chips = mesh.devices.size
    model = build(cfg)
    accum = _accum_for(cfg, shape)
    batch_sds = configs.input_specs(cfg, shape)
    if tier == "cost" and accum > 1:
        batch_sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(
                (x.shape[0] // accum,) + x.shape[1:], x.dtype), batch_sds)
    n_act = cfg.active_params()

    if shape.kind == "train":
        p_sh, o_sh, b_sh, (p_shapes, o_shapes) = train_lib.shardings_for(
            cfg, mesh, batch_sds, gathered_params=GATHERED_PARAMS)
        step = train_lib.make_train_step(
            cfg, adamw.AdamWConfig(), mesh,
            accum_steps=accum if tier == "full" else 1)
        with mesh_lib.set_mesh(mesh):
            lowered = jax.jit(
                step,
                in_shardings=(p_sh, o_sh, b_sh),
                out_shardings=(p_sh, o_sh, None),
                donate_argnums=(0, 1),
            ).lower(p_shapes, o_shapes, batch_sds)
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n_act * tokens
    elif shape.kind == "prefill":
        p_sh, _, b_sh, (p_shapes, _) = train_lib.shardings_for(
            cfg, mesh, batch_sds)
        step = train_lib.make_prefill_step(cfg)
        out_sh = _tok_out_sharding(mesh, shape.global_batch)
        with mesh_lib.set_mesh(mesh):
            lowered = jax.jit(
                step, in_shardings=(p_sh, b_sh), out_shardings=out_sh,
            ).lower(p_shapes, batch_sds)
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n_act * tokens
    else:  # decode
        p_sh, _, b_sh, (p_shapes, _) = train_lib.shardings_for(
            cfg, mesh, batch_sds)
        c_sh, c_shapes = train_lib.serve_shardings(
            cfg, mesh, shape.global_batch, shape.seq_len)
        step = train_lib.make_serve_step(cfg)
        out_sh = _tok_out_sharding(mesh, shape.global_batch)
        with mesh_lib.set_mesh(mesh):
            lowered = jax.jit(
                step, in_shardings=(p_sh, c_sh, b_sh),
                out_shardings=(out_sh, c_sh), donate_argnums=(1,),
            ).lower(p_shapes, c_shapes, batch_sds)
        tokens = shape.global_batch * 1
        model_flops = 2.0 * n_act * tokens

    t0 = time.perf_counter()
    compiled = lowered.compile()
    dt = time.perf_counter() - t0
    if verbose:
        print(f"  [{tier}] compiled in {dt:.1f}s")
    return lowered, compiled, model_flops, chips, accum


GATHERED_PARAMS = False   # cost-tier toggle for §Perf iteration 5


class SkipCell(Exception):
    pass


def _unit_layers(cfg) -> int:
    """Smallest homogeneous repeat unit (layers per scan group)."""
    if cfg.family == "ssm" and cfg.slstm_every:
        return cfg.slstm_every
    if cfg.family == "hybrid" and cfg.attn_every:
        return cfg.attn_every
    return 1


def cost_extrapolated(arch: str, shape_name: str, mesh, verbose=True):
    """Exact-by-linearity cost extraction: lower 1-unit and 2-unit UNROLLED
    models on one microbatch, difference them for the per-unit cost, and
    extrapolate to full depth. Valid because repeat units are identical
    (same shapes/ops per unit); the base term captures embedding, logits,
    loss and optimizer. ~100x faster than unrolling 60 layers.

    Returns (totals dict, accum, chips, model_flops, mem_cost_tier)."""
    cfg = configs.full_config(arch)
    unit = _unit_layers(cfg)
    n_units = cfg.n_layers // unit
    tail = cfg.n_layers - n_units * unit

    def measure(n_layers):
        _, comp, model_flops, chips, accum = lower_cell(
            arch, shape_name, mesh, verbose=verbose,
            overrides={"n_layers": n_layers}, tier="cost")
        rl = roofline.analyze(comp, chips, model_flops)
        mem = comp.memory_analysis()
        return rl, chips, accum, mem

    rl_a, chips, accum, mem_a = measure(unit)
    rl_b, _, _, mem_b = measure(2 * unit)
    per_unit = {
        "flops": rl_b.flops_global - rl_a.flops_global,
        "bytes": rl_b.hbm_bytes_global - rl_a.hbm_bytes_global,
        "link": rl_b.link_bytes_per_chip - rl_a.link_bytes_per_chip,
        "temp": (mem_b.temp_size_in_bytes - mem_a.temp_size_in_bytes),
    }
    base = {
        "flops": rl_a.flops_global - per_unit["flops"],
        "bytes": rl_a.hbm_bytes_global - per_unit["bytes"],
        "link": rl_a.link_bytes_per_chip - per_unit["link"],
        "temp": mem_a.temp_size_in_bytes - per_unit["temp"],
    }
    tot = {k: base[k] + n_units * per_unit[k] for k in base}
    if tail:  # hybrid tail = plain backbone layers (no shared-attn call)
        rl_c, _, _, mem_c = measure(unit + tail)
        per_tail = {
            "flops": (rl_c.flops_global - rl_a.flops_global) / tail,
            "bytes": (rl_c.hbm_bytes_global - rl_a.hbm_bytes_global) / tail,
            "link": (rl_c.link_bytes_per_chip
                     - rl_a.link_bytes_per_chip) / tail,
            "temp": (mem_c.temp_size_in_bytes
                     - mem_a.temp_size_in_bytes) / tail,
        }
        tot = {k: tot[k] + tail * per_tail[k] for k in tot}
    # collective op counts: same linear model
    counts = {}
    for k in set(rl_a.collectives["counts"]) | set(rl_b.collectives["counts"]):
        ca = rl_a.collectives["counts"].get(k, 0)
        cb = rl_b.collectives["counts"].get(k, 0)
        counts[k] = int(ca + (n_units - 1 + (tail / unit if unit else 0))
                        * (cb - ca)) if cb >= ca else ca
    # full-shape model flops
    n_act = cfg.active_params()
    shape = SHAPES[shape_name]
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mf = (6.0 if shape.kind == "train" else 2.0) * n_act * tokens
    args_bytes = mem_a.argument_size_in_bytes
    return tot, counts, accum, chips, mf, args_bytes


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True, cost_tier: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    tag = f"{arch} x {shape_name} x {'2x16x16' if multi_pod else '16x16'}"
    print(f"[dryrun] {tag}", flush=True)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    shape = SHAPES[shape_name]
    try:
        # tier FULL: compile + memory proof at the full global batch
        _, compiled, model_flops, chips, accum = lower_cell(
            arch, shape_name, mesh, verbose, tier="full")
    except SkipCell as e:
        print(f"  SKIP: {e}")
        rec.update(status="skip", reason=str(e))
        return rec
    mem = compiled.memory_analysis()
    rec.update(status="ok", accum_steps=accum,
               memory={k: int(getattr(mem, k, 0)) for k in
                       ("argument_size_in_bytes", "output_size_in_bytes",
                        "temp_size_in_bytes", "alias_size_in_bytes")})
    hbm_gib = (rec["memory"]["argument_size_in_bytes"]
               + rec["memory"]["temp_size_in_bytes"]) / 2**30
    if verbose:
        print(f"  memory/device: args+temp = {hbm_gib:.2f} GiB "
              f"(accum={accum})")

    if cost_tier:
        # tier COST: unit-differenced unrolled lowerings -> exact-by-
        # linearity roofline terms for the full depth, x accum for the
        # full global batch.
        tot, counts, accum, chips, model_flops, args_b = cost_extrapolated(
            arch, shape_name, mesh, verbose)
        sc = accum
        flops_g = tot["flops"] * sc
        bytes_g = tot["bytes"] * sc
        link_pc = tot["link"] * sc
        t_c = flops_g / (chips * roofline._PEAK_FLOPS)
        t_m = bytes_g / (chips * roofline._HBM_BW)
        # fusion-aware HBM estimate: peak-live temp (write+read) + args
        # read per microbatch — cost_analysis "bytes accessed" counts every
        # HLO op's operands as if unfused (pessimistic ~100x on TPU).
        est_bytes_dev = (2.0 * tot["temp"] + args_b) * sc
        t_m_est = est_bytes_dev / roofline._HBM_BW
        t_l = link_pc / roofline._LINK_BW
        dom = max((("compute", t_c), ("memory", t_m_est),
                   ("collective", t_l)), key=lambda kv: kv[1])[0]
        rec.update(
            flops_global=flops_g, hbm_bytes_global=bytes_g,
            hbm_bytes_est_per_dev=est_bytes_dev,
            link_bytes_per_chip=link_pc,
            t_compute_s=t_c, t_memory_s=t_m, t_memory_est_s=t_m_est,
            t_collective_s=t_l, dominant=dom, model_flops=model_flops,
            useful_ratio=model_flops / flops_g if flops_g else 0.0,
            collectives={"counts": counts})
        if verbose:
            print(f"  roofline: compute={t_c*1e3:.2f}ms "
                  f"memory(hlo)={t_m*1e3:.2f}ms "
                  f"memory(est)={t_m_est*1e3:.2f}ms "
                  f"collective={t_l*1e3:.2f}ms -> {dom}"
                  f" | useful={model_flops/flops_g if flops_g else 0:.2f}")
            print(f"  collectives: {counts}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    archs = configs.ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if (args.both_meshes or
                               (args.all and not args.multi_pod)) \
        else [args.multi_pod]

    results = []
    for mp in meshes:
        for arch in archs:
            for shp in shapes:
                try:
                    # roofline table is single-pod only (§Roofline); the
                    # multi-pod pass proves the 'pod' axis compiles
                    results.append(run_cell(arch, shp, mp,
                                            cost_tier=not mp))
                except Exception:
                    traceback.print_exc()
                    results.append({"arch": arch, "shape": shp,
                                    "mesh": "2x16x16" if mp else "16x16",
                                    "status": "error",
                                    "error": traceback.format_exc()[-2000:]})
                if args.out:
                    with open(args.out, "w") as f:
                        json.dump(results, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skip, {n_err} error")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
