"""SVM serving launcher — train (or compact) a model, stand up the
inference plane, report latency percentiles.

    python -m repro.launch.svm_serve --dataset a9a [--format ell] \
        [--use-pallas] [--shards 4] [--compact] [--dtype bfloat16] \
        [--batch 256] [--repeats 50] [--roofline]

Also reachable as ``python -m repro.launch.serve --svm ...`` (the unified
serving entry point; LM serving stays behind ``--arch``).
"""
import argparse
import json
import time

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="a9a")
    ap.add_argument("--heuristic", default="multi5pc")
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--format", default="dense", choices=("dense", "ell"))
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--shards", type=int, default=1,
                    help="mesh width for the SV axis (0 = all devices)")
    ap.add_argument("--compact", action="store_true",
                    help="serve the deduped/pruned deployment artifact")
    ap.add_argument("--dtype", default=None,
                    choices=(None, "float32", "bfloat16"),
                    help="SV storage dtype on device")
    ap.add_argument("--min-bucket", type=int, default=64)
    ap.add_argument("--max-bucket", type=int, default=4096)
    ap.add_argument("--batch", type=int, default=256,
                    help="query batch size for the latency report")
    ap.add_argument("--repeats", type=int, default=50)
    ap.add_argument("--roofline", action="store_true",
                    help="price the hot bucket executable against peak")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)

    from repro.core import SMOSolver, SVMConfig
    from repro.data import SPECS, make

    spec = SPECS[args.dataset]
    X, y, Xt, yt = make(args.dataset, scale=args.scale, seed=0)
    cfg = SVMConfig(C=spec.C, sigma2=spec.sigma2,
                    heuristic=args.heuristic, format=args.format,
                    use_pallas=args.use_pallas)
    model = SMOSolver(cfg).fit(X, y)
    if args.compact:
        model = model.compact(dtype=args.dtype)
        engine = model.serve_engine(
            shards=args.shards or None, min_bucket=args.min_bucket,
            max_bucket=args.max_bucket, use_pallas=args.use_pallas)
    else:
        engine = model.serve_engine(
            shards=args.shards or None, dtype=args.dtype,
            min_bucket=args.min_bucket, max_bucket=args.max_bucket,
            use_pallas=args.use_pallas)
    print(f"engine: {engine.describe()}")

    Zt = Xt if len(Xt) else X
    rng = np.random.default_rng(0)
    Z = Zt[rng.integers(0, len(Zt), size=args.batch)]
    engine.decision_function(Z)                     # warm the bucket
    lat = []
    for _ in range(args.repeats):
        t0 = time.perf_counter()
        engine.decision_function(Z)
        lat.append(time.perf_counter() - t0)
    lat = np.sort(np.asarray(lat))
    p50 = float(np.percentile(lat, 50))
    p99 = float(np.percentile(lat, 99))
    qps = args.batch / p50
    print(f"batch={args.batch}: p50={p50 * 1e3:.3f}ms p99={p99 * 1e3:.3f}ms "
          f"qps={qps:,.0f} us/query={p50 / args.batch * 1e6:.2f}")
    if len(yt):
        acc = float((np.where(engine.decision_function(Xt) >= 0.0, 1.0, -1.0)
                     == yt).mean())
        print(f"test acc: {acc:.4f}")
    report = {"engine": engine.describe(), "batch": args.batch,
              "p50_s": p50, "p99_s": p99, "qps": qps}
    if args.roofline:
        rf = engine.roofline(engine._bucket_of(args.batch)).row()
        print(f"roofline: dominant={rf['dominant']} "
              f"t_compute={rf['t_compute_s']:.2e}s "
              f"t_memory={rf['t_memory_s']:.2e}s "
              f"useful_ratio={rf['useful_ratio']:.3f}")
        report["roofline"] = rf
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(report, f, indent=2, default=str)
        print(f"wrote {args.json_out}")


if __name__ == "__main__":
    main()
