"""Production meshes.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required by the dry-run protocol, which must
set XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips).

    Axis roles (DESIGN.md §6):
      pod    inter-pod data parallelism (DCI links; gradient psum hierarchy)
      data   intra-pod data parallelism + FSDP/ZeRO param-and-moment sharding
      model  tensor / expert parallelism (+ SVM feature-dim sharding)
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import numpy as np
    need = int(np.prod(shape))
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
                         devices=jax.devices()[:need])


def make_host_mesh(max_devices: int | None = None):
    """Whatever this host offers, as a 1D 'data' mesh (tests/examples)."""
    n = len(jax.devices()) if max_devices is None else max_devices
    return jax.make_mesh((n,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))


def batch_axes(mesh) -> tuple:
    """Mesh axes that shard the batch: ('pod', 'data') when both exist."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)
