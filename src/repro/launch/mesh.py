"""Production meshes.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required by the dry-run protocol, which must
set XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """Version-compatible ``jax.make_mesh``.

    JAX >= 0.5 grew an ``axis_types`` kwarg (and ``jax.sharding.AxisType``);
    0.4.x has neither. All meshes in this repo want Auto axes — exactly the
    0.4.x default — so we pass ``axis_types`` only where it exists.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                axis_shapes, axis_names,
                axis_types=(axis_type.Auto,) * len(axis_names),
                devices=devices)
        except TypeError:  # AxisType exists but make_mesh predates the kwarg
            pass
    return jax.make_mesh(axis_shapes, axis_names, devices=devices)


def set_mesh(mesh):
    """Version-compatible ambient-mesh context.

    ``jax.set_mesh`` arrived after 0.4.x; there the ``Mesh`` object itself
    is the context manager that installs the ambient mesh.
    """
    sm = getattr(jax, "set_mesh", None)
    if sm is not None:
        return sm(mesh)
    return mesh


def shard_map_compat(f, *, mesh, in_specs, out_specs):
    """Version-compatible ``shard_map`` with replication checking off.

    JAX >= 0.7 exposes ``jax.shard_map(..., check_vma=...)``; 0.4.x has
    ``jax.experimental.shard_map.shard_map(..., check_rep=...)``.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    from jax.experimental.shard_map import shard_map as sm
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=False)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips).

    Axis roles (DESIGN.md §6):
      pod    inter-pod data parallelism (DCI links; gradient psum hierarchy)
      data   intra-pod data parallelism + FSDP/ZeRO param-and-moment sharding
      model  tensor / expert parallelism (+ SVM feature-dim sharding)
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import numpy as np
    need = int(np.prod(shape))
    return make_mesh(shape, axes, devices=jax.devices()[:need])


def make_host_mesh(max_devices: int | None = None):
    """Whatever this host offers, as a 1D 'data' mesh (tests/examples)."""
    n = len(jax.devices()) if max_devices is None else max_devices
    return make_mesh((n,), ("data",))


def batch_axes(mesh) -> tuple:
    """Mesh axes that shard the batch: ('pod', 'data') when both exist."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)
