"""Serving launcher: ``python -m repro.launch.serve --arch <id> ...``.
Thin CLI over the decode/prefill step builders (see examples/serve_lm.py)."""
import os
import runpy
import sys

if __name__ == "__main__":
    sys.argv[0] = "serve.py"
    runpy.run_path(os.path.join(os.path.dirname(__file__), "..", "..", "..",
                                "examples", "serve_lm.py"),
                   run_name="__main__")
