"""Unified serving launcher.

    python -m repro.launch.serve --svm --dataset a9a ...   # SVM inference
    python -m repro.launch.serve --arch <id> ...           # LM decode/prefill

``--svm`` dispatches to :mod:`repro.launch.svm_serve` (the production SVM
inference plane — ``core/serve.ServeEngine``); everything else falls
through to the LM serving example, which owns ``--arch`` and friends.
"""
import os
import runpy
import sys


def main(argv=None):
    argv = list(sys.argv[1:] if argv is None else argv)
    if "--svm" in argv:
        argv.remove("--svm")
        from repro.launch import svm_serve
        return svm_serve.main(argv)
    sys.argv = ["serve.py"] + argv
    runpy.run_path(os.path.join(os.path.dirname(__file__), "..", "..", "..",
                                "examples", "serve_lm.py"),
                   run_name="__main__")


if __name__ == "__main__":
    main()
