"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (EXPERIMENTS.md §Roofline):

  compute    = HLO_FLOPs_global  / (chips * 197e12 bf16 FLOP/s)
  memory     = HLO_bytes_global  / (chips * 819e9  B/s HBM)
  collective = link_bytes_per_chip / 50e9 B/s ICI
               (== collective_bytes_global / (chips * link_bw))

cost_analysis() reports the per-device (SPMD-partitioned) program, so
globals are per-device * chips. Collective bytes are NOT in cost_analysis:
we parse the optimized per-device HLO and charge each op the ring-algorithm
link traffic on its largest replica-group axis:

  all-gather      out_bytes * (g-1)/g      reduce-scatter  in_bytes * (g-1)/g
  all-reduce      2 * bytes * (g-1)/g      all-to-all      bytes * (g-1)/g
  collective-permute  bytes
"""
from __future__ import annotations

import dataclasses
import re

_PEAK_FLOPS = 197e12        # bf16 / chip (TPU v5e-class)
_HBM_BW = 819e9             # B/s / chip
_LINK_BW = 50e9             # B/s / link ICI

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*\(?([a-z0-9\[\],\s{}#*_.-]+?)\)?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.I)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shapes_str: str, f32_scale: float = 1.0) -> float:
    """f32_scale: XLA:CPU promotes bf16 arithmetic to f32, inflating every
    collective payload 2x relative to the TPU target where the model dtype
    is bf16 end-to-end. f32_scale=0.5 undoes that for f32 tensors (ints and
    true-f32 scalars are small; bounded error, documented in EXPERIMENTS)."""
    total = 0.0
    for m in _SHAPE_RE.finditer(shapes_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        b = n * _DTYPE_BYTES[dt]
        if dt == "f32":
            b *= f32_scale
        total += b
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    bytes_by_kind: dict
    link_bytes: float      # per-device ring-model link traffic

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_kind.values()))


def parse_collectives(hlo_text: str,
                      f32_scale: float = 1.0) -> CollectiveStats:
    counts: dict = {}
    byk: dict = {}
    link = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done(" in line:   # charge -start, skip -done twins
            continue
        kind = m.group(2).lower()
        size = _shape_bytes(m.group(1), f32_scale)
        g = 1
        gm = _GROUPS_RE.search(line)
        if gm:
            g = len([x for x in gm.group(1).split(",") if x.strip() != ""])
        else:
            gm2 = _GROUPS2_RE.search(line)
            if gm2:
                g = int(gm2.group(2))
        if g <= 1:
            continue
        frac = (g - 1) / g
        if kind == "all-reduce":
            link += 2.0 * size * frac
        elif kind == "collective-permute":
            link += size
        else:  # all-gather / reduce-scatter / all-to-all
            link += size * frac
        counts[kind] = counts.get(kind, 0) + 1
        byk[kind] = byk.get(kind, 0.0) + size
    return CollectiveStats(counts, byk, link)


@dataclasses.dataclass
class Roofline:
    flops_global: float
    hbm_bytes_global: float
    link_bytes_per_chip: float
    chips: int
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    model_flops: float
    useful_ratio: float
    collectives: dict
    bytes_per_device: float = 0.0

    def row(self) -> dict:
        return {
            "flops_global": self.flops_global,
            "hbm_bytes_global": self.hbm_bytes_global,
            "link_bytes_per_chip": self.link_bytes_per_chip,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "collectives": self.collectives,
            "bytes_per_device": self.bytes_per_device,
        }


def analyze(compiled, chips: int, model_flops: float,
            bf16_model: bool = True) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older API returned [dict]
        cost = cost[0]
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    coll = parse_collectives(compiled.as_text(),
                             0.5 if bf16_model else 1.0)
    flops_g = flops_dev * chips
    bytes_g = bytes_dev * chips
    t_c = flops_g / (chips * _PEAK_FLOPS)
    t_m = bytes_g / (chips * _HBM_BW)
    t_l = coll.link_bytes / _LINK_BW
    dom = max((("compute", t_c), ("memory", t_m), ("collective", t_l)),
              key=lambda kv: kv[1])[0]
    try:
        mem = compiled.memory_analysis()
        bpd = float(getattr(mem, "temp_size_in_bytes", 0) +
                    getattr(mem, "argument_size_in_bytes", 0) +
                    getattr(mem, "output_size_in_bytes", 0) -
                    getattr(mem, "alias_size_in_bytes", 0))
    except Exception:
        bpd = 0.0
    return Roofline(
        flops_global=flops_g, hbm_bytes_global=bytes_g,
        link_bytes_per_chip=coll.link_bytes, chips=chips,
        t_compute=t_c, t_memory=t_m, t_collective=t_l, dominant=dom,
        model_flops=model_flops,
        useful_ratio=model_flops / flops_g if flops_g else 0.0,
        collectives={"counts": coll.counts, "bytes": coll.bytes_by_kind},
        bytes_per_device=bpd)
