"""Elastic scaling + straggler mitigation utilities (DESIGN.md §6).

Elastic rescale is checkpoint-mediated: ``rescale`` restores a checkpoint
saved under ANY mesh onto the current one (restore() device_puts host
arrays under the new NamedShardings — the layouts need not match). The
deterministic token pipeline (pure function of step) replays the stream
exactly, so an N-pod -> M-pod move is bitwise-consistent modulo reduction
order.

StragglerWatchdog bounds the blast radius of a slow/hung host: it tracks a
robust step-time estimate and invokes a callback (checkpoint + alert in
train drivers) when a step exceeds ``threshold``x the running median —
on a real deployment the callback triggers the preemption/replace path,
here it checkpoints so the elastic restart path takes over.

Recovery path (save boundary == dispatch boundary == restore boundary),
shared by the LM trainer and the SVM epoch driver
(``SVMConfig(watchdog_threshold=...)`` wires this watchdog around the
fused-epoch dispatches; ``repro.core.driver`` has the SVM-side view):

    start_step ─▶ dispatch ─▶ end_step ─┬─ ok ───────▶ next dispatch
                                        └─ straggle ─▶ on_straggle:
                                              force an atomic checkpoint
                                              at THIS dispatch boundary
                                              (+ shrink the per-dispatch
                                              budget), so the elastic
                                              restart below loses nothing
    crash / preemption / rescale ─▶ rescale(): restore the newest
    COMPLETE step onto the CURRENT mesh — checkpoints hold host arrays
    only, so N -> M devices is the same code path as a plain restart.
"""
from __future__ import annotations

import os
import time
from typing import Callable, Optional

from repro.ckpt import checkpoint as ckpt


def rescale(ckpt_base: str, like_trees: dict, shardings: dict,
            step: Optional[int] = None) -> tuple[dict, int]:
    """Restore the latest (or given) step onto the CURRENT mesh/shardings.

    like_trees/shardings: {'params': ..., 'opt': ...} pytrees (shapes may be
    ShapeDtypeStructs). Returns (restored groups, step). With no explicit
    ``step``, torn or content-corrupt step dirs are skipped — the newest
    step whose checksums verify wins (an explicit step is a caller
    decision; restore() still raises on mismatch there)."""
    if step is None:
        steps = ckpt.complete_steps(ckpt_base)
        step = steps[-1] if steps else None
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_base}")
    d = os.path.join(ckpt_base, f"step_{step}")
    out = {name: ckpt.restore(d, name, like_trees[name],
                              shardings.get(name))
           for name in like_trees}
    return out, step


class StragglerWatchdog:
    """Step-time anomaly detector with a bounded-memory running median."""

    def __init__(self, threshold: float = 3.0, window: int = 32,
                 on_straggle: Optional[Callable[[int, float, float], None]]
                 = None, warmup: int = 3):
        self.threshold = threshold
        self.window = window
        self.on_straggle = on_straggle
        self.warmup = warmup
        self._times: list[float] = []
        self._last = None
        self._step = 0
        self.events: list[tuple[int, float, float]] = []

    def start_step(self):
        self._last = time.perf_counter()

    def end_step(self) -> bool:
        """Returns True if this step straggled."""
        assert self._last is not None, "start_step() not called"
        dt = time.perf_counter() - self._last
        self._step += 1
        if len(self._times) >= self.warmup:
            med = sorted(self._times)[len(self._times) // 2]
            if dt > self.threshold * med:
                self.events.append((self._step, dt, med))
                if self.on_straggle:
                    self.on_straggle(self._step, dt, med)
                return True
        self._times.append(dt)
        if len(self._times) > self.window:
            self._times.pop(0)
        return False
