"""SVM training launcher — the paper's algorithm as a CLI.

    python -m repro.launch.svm_train --dataset a9a --heuristic multi5pc \
        [--scale 0.05] [--ckpt-dir ckpt/ --resume] [--parallel]

Multi-class datasets (``covtype``, ``news20``) train one-vs-rest: K
binary problems as ONE batched fit over a shared device mirror
(``core.multi.MultiProblemDriver``; ``--multi-backend loop`` is the
sequential parity oracle). ``--grid-c`` sweeps a C grid the same way on a
binary dataset — one problem per grid point, C traced per problem.
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="a9a")
    ap.add_argument("--heuristic", default="multi5pc")
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--eps", type=float, default=1e-3)
    ap.add_argument("--chunk-iters", type=int, default=256)
    ap.add_argument("--fuse-iters", type=int, default=1,
                    help="SMO segments fused into one device dispatch "
                         "(each up to --chunk-iters iterations); the host "
                         "reads back one fixed-size summary per dispatch. "
                         "Any value is bit-identical to 1 — raise it to "
                         "amortize dispatch overhead on small problems")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--parallel", action="store_true",
                    help="shard_map over all visible devices")
    ap.add_argument("--devices", type=int, default=None,
                    help="train on the first N visible devices (implies "
                         "--parallel). Checkpoints are mesh-portable: "
                         "--resume re-deals a run saved under ANY device "
                         "count onto this one")
    ap.add_argument("--watchdog-threshold", type=float, default=0.0,
                    help="arm the straggler watchdog: a dispatch slower "
                         "than this multiple of the running median forces "
                         "a checkpoint and halves the fused segment "
                         "budget (0 = off)")
    ap.add_argument("--chaos", default=None,
                    help="fault-injection spec (kill@I | kill-save@K | "
                         "delay@I:S | delay-all@I:S) — see "
                         "repro.launch.chaos; for testing the recovery "
                         "path from the command line")
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--format", default="dense", choices=("dense", "ell"),
                    help="sample storage: dense or block-ELL sparse")
    ap.add_argument("--selection", default="wss1", choices=("wss1", "wss2"),
                    help="working-set selection: first- or second-order")
    ap.add_argument("--row-cache", action="store_true",
                    help="device-resident LRU kernel-row cache (exact: "
                         "identical trajectory, fewer kernel-row passes)")
    ap.add_argument("--row-cache-slots", type=int, default=64)
    ap.add_argument("--row-cache-policy", default="lru",
                    choices=("lru", "slru"),
                    help="cache eviction: plain LRU or scan-resistant "
                         "segmented LRU (both exact)")
    ap.add_argument("--compact-backend", default="device",
                    choices=("device", "host"),
                    help="physical compaction: jitted on-device gather "
                         "(default) or host store rebuild (parity oracle)")
    ap.add_argument("--mirror", default="auto",
                    choices=("auto", "device", "host"),
                    help="device-resident full-set mirror: jitted Alg. 6 "
                         "reconstruction + device-side un-shrink when it "
                         "fits ('auto'), forced ('device', errors over "
                         "budget), or host-streaming oracle ('host')")
    ap.add_argument("--mirror-budget-bytes", type=int, default=None,
                    help="per-device byte cap for the mirror (default: "
                         "a fraction of reported device memory)")
    ap.add_argument("--multi-backend", default="batched",
                    choices=("batched", "loop"),
                    help="multi-problem training (multi-class datasets, "
                         "--grid-c): one fused K-problem device program "
                         "('batched') or K sequential fits ('loop', the "
                         "parity oracle)")
    ap.add_argument("--grid-c", default=None,
                    help="comma-separated C values: hyperparameter sweep "
                         "on a binary dataset, one problem per value "
                         "batched over the shared store")
    args = ap.parse_args()

    from repro.core import SMOSolver, SVMConfig
    from repro.data import SPECS, make

    spec = SPECS[args.dataset]
    X, y, Xt, yt = make(args.dataset, scale=args.scale, seed=0)
    cfg = SVMConfig(C=spec.C, sigma2=spec.sigma2, eps=args.eps,
                    heuristic=args.heuristic, chunk_iters=args.chunk_iters,
                    fuse_iters=args.fuse_iters,
                    checkpoint_dir=args.ckpt_dir, resume=args.resume,
                    use_pallas=args.use_pallas, format=args.format,
                    selection=args.selection, row_cache=args.row_cache,
                    row_cache_slots=args.row_cache_slots,
                    row_cache_policy=args.row_cache_policy,
                    compact_backend=args.compact_backend,
                    mirror=args.mirror,
                    mirror_budget_bytes=args.mirror_budget_bytes,
                    watchdog_threshold=args.watchdog_threshold)
    if args.devices is not None:
        args.parallel = True
    if args.chaos:
        from repro.launch import chaos
        chaos.install(chaos.parse_spec(args.chaos))
    if spec.n_classes > 2 or args.grid_c:
        from repro.core import MultiProblemDriver
        mesh = None
        if args.devices is not None:
            from repro.core.parallel import data_mesh
            mesh = data_mesh(args.devices)
        drv = MultiProblemDriver(cfg, backend=args.multi_backend,
                                 parallel=args.parallel, mesh=mesh)
        if args.grid_c:
            assert spec.n_classes == 2, "--grid-c needs a binary dataset"
            Cs = [float(c) for c in args.grid_c.split(",")]
            models = drv.fit_grid(X, y, Cs)
            for k, (C, m) in enumerate(zip(Cs, models)):
                # batched: all models share ONE stats with a K-entry
                # per_problem table; loop oracle: each model carries its
                # own scalar stats
                rec = next((r for r in m.stats.per_problem
                            if r["problem"] == k),
                           {"iterations": m.stats.iterations,
                            "n_sv": m.stats.n_sv})
                print(f"{args.dataset}/C={C:g}: "
                      f"iters={rec['iterations']} nsv={rec['n_sv']} "
                      f"obj={m.dual_objective():.4f}")
            return
        mdl = drv.fit_ovr(X, y)
        st = mdl.stats
        train = sum({id(m.stats): m.stats.train_time
                     for m in mdl.models}.values())
        tot = sum(r["iterations"] for r in st.per_problem)
        cache = (f" cache_hit={st.cache_hit_rate:.2f}"
                 if args.row_cache else "")
        print(f"{args.dataset}/ovr{len(mdl.classes)}/{args.multi_backend}: "
              f"iters={tot} nsv={st.n_sv} train={train:.2f}s{cache}")
        if len(yt):
            print(f"test acc: {(mdl.predict(Xt) == yt).mean():.4f}")
        return
    if args.parallel:
        from repro.core.parallel import ParallelSMOSolver
        solver = ParallelSMOSolver(cfg, devices=args.devices)
    else:
        solver = SMOSolver(cfg)
    m = solver.fit(X, y)
    s = m.stats
    cache = (f" cache_hit={s.cache_hit_rate:.2f}" if args.row_cache else "")
    print(f"{args.dataset}/{args.heuristic}: iters={s.iterations} "
          f"nsv={s.n_sv} conv={s.converged} recon={s.reconstructions} "
          f"mirror={s.mirror} train={s.train_time:.2f}s "
          f"recon_t={s.recon_time:.2f}s{cache}")
    if len(yt):
        print(f"test acc: {(m.predict(Xt) == yt).mean():.4f}")


if __name__ == "__main__":
    main()
