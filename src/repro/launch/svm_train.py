"""SVM training launcher — the paper's algorithm as a CLI.

    python -m repro.launch.svm_train --dataset a9a --heuristic multi5pc \
        [--scale 0.05] [--ckpt-dir ckpt/ --resume] [--parallel]
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="a9a")
    ap.add_argument("--heuristic", default="multi5pc")
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--eps", type=float, default=1e-3)
    ap.add_argument("--chunk-iters", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--parallel", action="store_true",
                    help="shard_map over all visible devices")
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--format", default="dense", choices=("dense", "ell"),
                    help="sample storage: dense or block-ELL sparse")
    args = ap.parse_args()

    from repro.core import SMOSolver, SVMConfig
    from repro.data import SPECS, make

    spec = SPECS[args.dataset]
    X, y, Xt, yt = make(args.dataset, scale=args.scale, seed=0)
    cfg = SVMConfig(C=spec.C, sigma2=spec.sigma2, eps=args.eps,
                    heuristic=args.heuristic, chunk_iters=args.chunk_iters,
                    checkpoint_dir=args.ckpt_dir, resume=args.resume,
                    use_pallas=args.use_pallas, format=args.format)
    if args.parallel:
        from repro.core.parallel import ParallelSMOSolver
        solver = ParallelSMOSolver(cfg)
    else:
        solver = SMOSolver(cfg)
    m = solver.fit(X, y)
    s = m.stats
    print(f"{args.dataset}/{args.heuristic}: iters={s.iterations} "
          f"nsv={s.n_sv} conv={s.converged} recon={s.reconstructions} "
          f"train={s.train_time:.2f}s recon_t={s.recon_time:.2f}s")
    if len(yt):
        print(f"test acc: {(m.predict(Xt) == yt).mean():.4f}")


if __name__ == "__main__":
    main()
