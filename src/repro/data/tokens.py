"""Deterministic token pipeline for the LM architectures.

Stateless skip-ahead: batch(step) is a pure function of (seed, step), so a
restarted or elastically-rescaled job replays the exact stream from its
checkpointed step — the fault-tolerance contract in DESIGN.md §6. The
synthetic stream is a mixture of Zipf-distributed unigrams and short
repeated motifs (gives a learnable signal so example training losses fall).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenPipeline:
    vocab_size: int
    batch: int            # global batch (callers shard it over the mesh)
    seq_len: int
    seed: int = 0
    zipf_a: float = 1.3
    motif_len: int = 8
    n_motifs: int = 64

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self._motifs = rng.integers(
            1, self.vocab_size, size=(self.n_motifs, self.motif_len))

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        """Returns {tokens, targets}: (B, L) int32 each; targets are
        next-token shifted with -1 padding on the final position."""
        rng = np.random.default_rng((self.seed << 20) ^ step)
        base = rng.zipf(self.zipf_a, size=(self.batch, self.seq_len + 1))
        toks = np.minimum(base, self.vocab_size - 1).astype(np.int32)
        # overwrite random spans with motifs (predictable structure)
        n_spans = max(1, self.seq_len // (4 * self.motif_len))
        for b in range(self.batch):
            ids = rng.integers(0, self.n_motifs, size=n_spans)
            starts = rng.integers(0, self.seq_len - self.motif_len,
                                  size=n_spans)
            for m, s in zip(ids, starts):
                toks[b, s: s + self.motif_len] = self._motifs[m]
        return {"tokens": toks[:, :-1],
                "targets": toks[:, 1:].astype(np.int32)}

    def shard_for(self, step: int, host_id: int, n_hosts: int):
        """Per-host slice of the global batch (multi-host data loading)."""
        full = self.batch_at(step)
        per = self.batch // n_hosts
        sl = slice(host_id * per, (host_id + 1) * per)
        return {k: v[sl] for k, v in full.items()}
