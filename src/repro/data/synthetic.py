"""Synthetic stand-ins for the paper's seven evaluation datasets.

The container has no network access, so the public datasets (Table 2 of the
paper) are replaced by generators matched on the axes that drive SMO/shrinking
behaviour: N, d, sparsity/density, feature type (binary categorical vs dense
continuous), class balance, and separability (which controls the
support-vector fraction |zeta|/|X| — the quantity the paper's heuristics key
on). Hyperparameters (C, sigma^2) are the paper's Table 2 values.

Every generator is deterministic in (spec, seed, scale).
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Literal

import numpy as np


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    n_train: int
    n_test: int
    d: int
    kind: Literal["dense_clusters", "sparse_binary", "image_like",
                  "forest_like", "text_topics"]
    C: float
    sigma2: float
    density: float = 1.0     # fraction of nonzero features
    separation: float = 2.0  # inter-class margin in units of cluster sigma
    label_noise: float = 0.02
    n_clusters: int = 4      # per class, for multi-modal structure
    n_classes: int = 2       # >2 -> integer labels in {0..K-1} (OvR specs)


# Table 2 of the paper, with measured densities of the public originals.
SPECS: dict[str, DatasetSpec] = {s.name: s for s in [
    DatasetSpec("mnist", 60000, 10000, 784, "image_like", C=10, sigma2=25,
                density=0.19, separation=1.6, n_clusters=10),
    DatasetSpec("a7a", 16100, 16461, 123, "sparse_binary", C=32, sigma2=64,
                density=0.11, separation=1.1, label_noise=0.12),
    DatasetSpec("a9a", 32561, 16281, 123, "sparse_binary", C=32, sigma2=64,
                density=0.11, separation=1.1, label_noise=0.12),
    DatasetSpec("usps", 7291, 2007, 256, "image_like", C=8, sigma2=16,
                density=0.75, separation=2.2, n_clusters=10),
    DatasetSpec("mushrooms", 8124, 0, 112, "sparse_binary", C=8, sigma2=64,
                density=0.19, separation=3.0, label_noise=0.0),
    DatasetSpec("w7a", 24692, 25057, 300, "sparse_binary", C=32, sigma2=64,
                density=0.04, separation=1.8, label_noise=0.03),
    DatasetSpec("ijcnn", 49990, 91701, 22, "dense_clusters", C=0.5, sigma2=1,
                density=1.0, separation=1.0, label_noise=0.08, n_clusters=6),
    # Multi-class OvR workloads (batched multi-problem driver): integer
    # labels in {0..n_classes-1}, matched to the public originals on
    # (N, d, K, density, class balance).
    DatasetSpec("covtype", 522910, 58102, 54, "forest_like", C=10, sigma2=16,
                density=1.0, separation=1.3, label_noise=0.0, n_clusters=3,
                n_classes=7),
    # news20's vocabulary (62061 terms) is scaled to a CI-budget d at the
    # REAL ~80 nonzero terms/doc (0.0013 * 62061): nnz/row is what drives
    # ELL lane budgets and kernel-row cost, not the raw vocabulary width.
    DatasetSpec("news20", 15935, 3993, 8192, "text_topics", C=4, sigma2=64,
                density=0.0098, separation=3.0, label_noise=0.0,
                n_classes=20),
]}


def _dense_clusters(rng, n, spec: DatasetSpec):
    """Two classes of gaussian cluster mixtures; separation controls |zeta|."""
    k = spec.n_clusters
    centers_p = rng.normal(size=(k, spec.d))
    centers_m = rng.normal(size=(k, spec.d))
    # push the two banks apart along a random direction
    u = rng.normal(size=spec.d)
    u /= np.linalg.norm(u)
    centers_p += spec.separation * 0.5 * u
    centers_m -= spec.separation * 0.5 * u
    y = np.where(rng.random(n) < 0.5, 1.0, -1.0)
    comp = rng.integers(0, k, size=n)
    X = np.where(y[:, None] > 0, centers_p[comp], centers_m[comp])
    X = X + rng.normal(scale=1.0, size=(n, spec.d))
    return X.astype(np.float32), y.astype(np.float32)


def _image_like(rng, n, spec: DatasetSpec):
    """Nonneg [0,1] features, block-sparse rows — digit-image statistics.
    Classes = even/odd "digit" prototypes (the paper's MNIST binarization)."""
    k = spec.n_clusters
    protos = rng.random((k, spec.d)) * (rng.random((k, spec.d)) < spec.density)
    digit = rng.integers(0, k, size=n)
    y = np.where(digit % 2 == 0, -1.0, 1.0)       # even -> -1, odd -> +1
    X = protos[digit] * (0.6 + 0.8 * rng.random((n, spec.d)))
    X += (spec.separation / 10.0) * rng.normal(size=(n, spec.d)) \
        * (protos[digit] > 0)
    X = np.clip(X, 0.0, 1.0)
    return X.astype(np.float32), y.astype(np.float32)


def _sparse_binary(rng, n, spec: DatasetSpec):
    """Categorical one-hot groups (census/web-text statistics): d features
    split into groups; each sample activates one feature per group. Labels
    from a sparse linear rule + noise -> controls SV fraction."""
    n_active = max(2, int(spec.density * spec.d))
    group_sizes = np.full(n_active, spec.d // n_active)
    group_sizes[: spec.d % n_active] += 1
    offsets = np.concatenate([[0], np.cumsum(group_sizes)[:-1]])
    choices = (rng.random((n, n_active)) * group_sizes).astype(np.int64)
    cols = offsets[None, :] + choices
    X = np.zeros((n, spec.d), np.float32)
    X[np.arange(n)[:, None], cols] = 1.0
    w = rng.normal(size=spec.d) * (rng.random(spec.d) < 0.6)
    score = X @ w + 0.3 * rng.normal(size=n)
    y = np.where(score > np.median(score), 1.0, -1.0)
    flip = rng.random(n) < spec.label_noise
    y = np.where(flip, -y, y)
    return X, y.astype(np.float32)


def _forest_like(rng, n, spec: DatasetSpec):
    """covtype statistics: dense continuous cartographic features, K
    imbalanced classes (two dominant cover types, geometric tail), each a
    mixture of terrain blobs ordered along an elevation-like direction;
    a block of quantized soil/wilderness indicator columns."""
    K = spec.n_classes
    pri = 0.55 ** np.arange(K)
    pri /= pri.sum()
    u = rng.normal(size=spec.d)
    u /= np.linalg.norm(u)
    centers = rng.normal(size=(K, spec.n_clusters, spec.d))
    centers += spec.separation * np.linspace(-1.0, 1.0, K)[:, None, None] * u
    y = rng.choice(K, size=n, p=pri)
    comp = rng.integers(0, spec.n_clusters, size=n)
    X = centers[y, comp] + rng.normal(size=(n, spec.d))
    nq = max(2, spec.d // 10)
    X[:, -nq:] = (X[:, -nq:] > 0.5).astype(np.float64)
    return X.astype(np.float32), y.astype(np.int32)


def _text_topics(rng, n, spec: DatasetSpec):
    """news20 statistics: K topical classes over a large vocabulary. Each
    document draws ~density*d distinct terms from its class topic mixed
    with a Zipf background (Gumbel top-k, vectorized), tf-idf-ish positive
    magnitudes, rows l2-normalized."""
    K = spec.n_classes
    nnz = max(4, int(round(spec.density * spec.d)))
    bg = 1.0 / np.arange(1, spec.d + 1)
    topic = np.zeros((K, spec.d))
    for k in range(K):
        cols = rng.choice(spec.d, size=min(spec.d, max(nnz * 4, 16)),
                          replace=False)
        topic[k, cols] = rng.random(cols.size) * spec.separation * bg.mean()
    y = rng.integers(0, K, size=n)
    w = bg[None, :] + topic[y] * spec.d
    g = -np.log(-np.log(rng.random((n, spec.d)) + 1e-12) + 1e-12)
    keys = np.log(w) + g
    cols = np.argpartition(-keys, nnz - 1, axis=1)[:, :nnz]
    vals = np.exp(0.4 * rng.normal(size=(n, nnz))).astype(np.float32)
    X = np.zeros((n, spec.d), np.float32)
    X[np.arange(n)[:, None], cols] = vals
    X /= np.maximum(np.linalg.norm(X, axis=1, keepdims=True), 1e-6)
    return X, y.astype(np.int32)


_GEN = {"dense_clusters": _dense_clusters, "image_like": _image_like,
        "sparse_binary": _sparse_binary, "forest_like": _forest_like,
        "text_topics": _text_topics}


def make(spec: "DatasetSpec | str", scale: float = 1.0, seed: int = 0):
    """Returns (X_train, y_train, X_test, y_test). ``scale`` shrinks N
    (CPU-friendly benchmark sizes) without changing d or statistics.
    Binary specs label with float32 +-1; multi-class specs
    (``spec.n_classes > 2`` — the covtype/news20 stand-ins) label with
    int32 class ids, the input of one-vs-rest training
    (``core.multi.train_ovr``)."""
    if isinstance(spec, str):
        spec = SPECS[spec]
    # crc32, not hash(): str hashing is salted per process, which made the
    # "deterministic in (spec, seed, scale)" contract silently false across
    # runs (two identical CLI invocations trained on different datasets)
    rng = np.random.default_rng(seed + zlib.crc32(spec.name.encode()) % 2**16)
    n_tr = max(64, int(spec.n_train * scale))
    n_te = int(spec.n_test * scale)
    X, y = _GEN[spec.kind](rng, n_tr + max(n_te, 0), spec)
    # balance check: ensure at least two classes present
    if np.unique(y[:n_tr]).size < 2:
        if spec.n_classes > 2:
            y[: n_tr // 2] = (y[0] + 1) % spec.n_classes
        else:
            y[: n_tr // 2] = -y[0]
    return X[:n_tr], y[:n_tr], X[n_tr:], y[n_tr:]


def density(X: np.ndarray) -> float:
    return float(np.count_nonzero(X)) / X.size


def make_sparse(n: int, d: int, density: float, seed: int = 0,
                noise: float = 0.3, label_noise: float = 0.02,
                margin: float = 0.0):
    """Sparse continuous-feature dataset with *exact* controllable density.

    Stand-in for the paper's large sparse workloads (rcv1/webspam class:
    text n-gram features, density well under 1%). Every row gets exactly
    ``round(density * d)`` nonzero features at uniform-random columns with
    log-normal-ish magnitudes; labels come from a sparse linear teacher so
    the SV fraction stays moderate. ``margin`` in [0, 1) discards that
    fraction of borderline samples (closest to the teacher's boundary),
    which lowers the SV fraction — the quantity shrinking heuristics key
    on. Returns (X, y) with X dense (convert via ``repro.data.to_ell`` /
    ``format='ell'`` for sparse storage).
    """
    rng = np.random.default_rng(seed)
    n_gen = int(np.ceil(n / max(1.0 - margin, 1e-6)))
    nnz = max(1, int(round(density * d)))
    # unique columns per row: argpartition of random keys (vectorized)
    keys = rng.random((n_gen, d))
    cols = np.argpartition(keys, nnz - 1, axis=1)[:, :nnz]
    vals = rng.normal(size=(n_gen, nnz)).astype(np.float32) * \
        np.exp(0.5 * rng.normal(size=(n_gen, nnz))).astype(np.float32)
    X = np.zeros((n_gen, d), np.float32)
    X[np.arange(n_gen)[:, None], cols] = vals
    w = rng.normal(size=d) * (rng.random(d) < 0.5)
    score = X @ w + noise * rng.normal(size=n_gen)
    score -= np.median(score)
    if margin > 0.0:
        keep = np.argsort(-np.abs(score))[:n]    # widest-margin samples
        keep = keep[rng.permutation(keep.size)]
        X, score = X[keep], score[keep]
    y = np.where(score > 0, 1.0, -1.0)
    flip = rng.random(y.size) < label_noise
    y = np.where(flip, -y, y).astype(np.float32)
    if np.all(y == y[0]):
        y[: y.size // 2] = -y[0]
    return X[:n], y[:n]


def make_repeat_heavy(n: int = 2048, d: int = 768, density: float = 0.25,
                      sep: float = 0.8, seed: int = 1):
    """Repeat-heavy SMO workload: two overlapping sparse Gaussian blobs.

    Driven to a low tolerance, the maximal-violating-pair loop spends a
    long convergence tail bouncing inside a hot working set — the access
    pattern the kernel-row LRU cache (``SVMConfig(row_cache=True)``)
    amortizes. The canonical workload of the cache benchmark
    (``benchmarks/sparse_bench.py --cache-out``) and the example; keep the
    three consumers on this one generator so they measure the same thing.
    Returns (X, y), X dense at the given Bernoulli density.
    """
    rng = np.random.default_rng(seed)
    X = np.vstack([rng.normal(+sep, 1, (n // 2, d)),
                   rng.normal(-sep, 1, (n - n // 2, d))]).astype(np.float32)
    X *= rng.random((n, d)) < density
    y = np.concatenate([np.ones(n // 2),
                        -np.ones(n - n // 2)]).astype(np.float32)
    return X, y
