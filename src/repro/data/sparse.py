"""Sparse sample storage: CSR (the paper's format) and block-ELL (our TPU
adaptation, DESIGN.md §2).

CSR here is the classic three-array layout (Fig. 1b/1c of the paper, minus
the co-located alpha/y/gamma cells — those live in the SVMState pytree, which
is the same co-location argument realized as structure-of-arrays). ELL pads
every row to a fixed nonzero budget K (multiple of 128 for TPU lanes) so the
Pallas gather kernel can stream (vals, cols) tiles.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSRMatrix:
    data: np.ndarray      # (nnz,) f32
    indices: np.ndarray   # (nnz,) i32 column ids
    indptr: np.ndarray    # (N+1,) i64 row pointers (the paper's psi)
    shape: tuple[int, int]

    @property
    def nnz(self) -> int:
        return int(self.data.size)

    def row(self, i: int) -> tuple[np.ndarray, np.ndarray]:
        lo, hi = self.indptr[i], self.indptr[i + 1]
        return self.data[lo:hi], self.indices[lo:hi]

    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.shape, np.float32)
        n = self.shape[0]
        for i in range(n):
            v, c = self.row(i)
            out[i, c] = v
        return out

    def memory_bytes(self) -> int:
        return self.data.nbytes + self.indices.nbytes + self.indptr.nbytes


@dataclasses.dataclass
class ELLMatrix:
    vals: np.ndarray      # (N, K) f32, zero-padded
    cols: np.ndarray      # (N, K) i32, zero-padded (val 0 makes it inert)
    shape: tuple[int, int]

    @property
    def K(self) -> int:
        return int(self.vals.shape[1])

    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.shape, np.float32)
        n = self.shape[0]
        rows = np.repeat(np.arange(n), self.K)
        np.add.at(out, (rows, self.cols.reshape(-1)), self.vals.reshape(-1))
        return out

    def memory_bytes(self) -> int:
        return self.vals.nbytes + self.cols.nbytes

    def sq_norms(self) -> np.ndarray:
        return (self.vals ** 2).sum(axis=1).astype(np.float32)


def to_csr(X: np.ndarray) -> CSRMatrix:
    n, d = X.shape
    mask = X != 0
    counts = mask.sum(axis=1)
    indptr = np.zeros(n + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    cols = np.nonzero(mask)[1].astype(np.int32)
    data = X[mask].astype(np.float32)
    return CSRMatrix(data, cols, indptr, (n, d))


def to_ell(X: np.ndarray, K: int | None = None, lane: int = 128) -> ELLMatrix:
    """Pad rows to K nonzeros (default: max row nnz rounded up to ``lane``)."""
    n, d = X.shape
    mask = X != 0
    counts = mask.sum(axis=1)
    kmax = int(counts.max()) if n else 0
    if K is None:
        K = max(lane, -(-kmax // lane) * lane)
    if kmax > K:
        raise ValueError(f"row with {kmax} nnz exceeds K={K}")
    # stable argsort of ~mask packs each row's nonzero columns (in order)
    # into the first slots; the padding tail is masked to (val=0, col=0)
    order = np.argsort(~mask, axis=1, kind="stable")[:, :K]
    taken = np.take_along_axis(mask, order, axis=1)
    vals = np.take_along_axis(X, order, axis=1).astype(np.float32) * taken
    cols = (order * taken).astype(np.int32)
    return ELLMatrix(vals, cols, (n, d))


def csr_space_report(X: np.ndarray) -> dict:
    """Fig. 1b: memory conserved by sparse formats vs dense."""
    dense = X.nbytes
    csr = to_csr(X).memory_bytes()
    ell = to_ell(X).memory_bytes()
    return {
        "dense_bytes": dense,
        "csr_bytes": csr,
        "ell_bytes": ell,
        "csr_saving_pct": 100.0 * (1 - csr / dense),
        "ell_saving_pct": 100.0 * (1 - ell / dense),
        "density": float(np.count_nonzero(X)) / X.size,
    }
