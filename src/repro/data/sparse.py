"""Sparse sample storage: CSR (the paper's format) and block-ELL (our TPU
adaptation, DESIGN.md §2).

CSR here is the classic three-array layout (Fig. 1b/1c of the paper, minus
the co-located alpha/y/gamma cells — those live in the SVMState pytree, which
is the same co-location argument realized as structure-of-arrays). ELL pads
every row to a fixed nonzero budget K (multiple of 128 for TPU lanes) so the
Pallas gather kernel can stream (vals, cols) tiles.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSRMatrix:
    data: np.ndarray      # (nnz,) f32
    indices: np.ndarray   # (nnz,) i32 column ids
    indptr: np.ndarray    # (N+1,) i64 row pointers (the paper's psi)
    shape: tuple[int, int]

    @property
    def nnz(self) -> int:
        return int(self.data.size)

    def row_nnz(self) -> np.ndarray:
        """Per-row nonzero counts (N,) — the quantity adaptive K tracks."""
        return np.diff(self.indptr).astype(np.int64)

    def row(self, i: int) -> tuple[np.ndarray, np.ndarray]:
        lo, hi = self.indptr[i], self.indptr[i + 1]
        return self.data[lo:hi], self.indices[lo:hi]

    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.shape, np.float32)
        rows = np.repeat(np.arange(self.shape[0]), self.row_nnz())
        out[rows, self.indices] = self.data
        return out

    def memory_bytes(self) -> int:
        return self.data.nbytes + self.indices.nbytes + self.indptr.nbytes


@dataclasses.dataclass
class ELLMatrix:
    vals: np.ndarray      # (N, K) f32, zero-padded
    cols: np.ndarray      # (N, K) i32, zero-padded (val 0 makes it inert)
    shape: tuple[int, int]

    @property
    def K(self) -> int:
        return int(self.vals.shape[1])

    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.shape, np.float32)
        n = self.shape[0]
        rows = np.repeat(np.arange(n), self.K)
        np.add.at(out, (rows, self.cols.reshape(-1)), self.vals.reshape(-1))
        return out

    def memory_bytes(self) -> int:
        return self.vals.nbytes + self.cols.nbytes

    def sq_norms(self) -> np.ndarray:
        return (self.vals ** 2).sum(axis=1).astype(np.float32)


def as_csr(obj) -> CSRMatrix:
    """Coerce CSR-like input to :class:`CSRMatrix` without densifying.

    Accepts a ``CSRMatrix``, any scipy-style object exposing
    ``data``/``indices``/``indptr``/``shape``, or a ``(data, indices,
    indptr, shape)`` tuple. Arrays are cast (f32 values, i32 columns,
    i64 row pointers) but never expanded to dense.
    """
    if isinstance(obj, CSRMatrix):
        src = obj
    elif all(hasattr(obj, a) for a in ("data", "indices", "indptr", "shape")):
        # CSC/BSR expose the same three arrays with different semantics —
        # reading them row-wise silently trains on the wrong matrix.
        fmt = getattr(obj, "format", "csr")
        if fmt != "csr":
            raise TypeError(
                f"expected CSR input, got scipy format {fmt!r}; convert "
                "with .tocsr() first")
        src = obj
    elif isinstance(obj, (tuple, list)) and len(obj) == 4:
        data, indices, indptr, shape = obj
        src = CSRMatrix(np.asarray(data), np.asarray(indices),
                        np.asarray(indptr), tuple(shape))
    else:
        raise TypeError(
            f"cannot interpret {type(obj).__name__} as CSR; want CSRMatrix, "
            "a scipy-like csr object, or (data, indices, indptr, shape)")
    data = np.ascontiguousarray(src.data, np.float32)
    indices = np.ascontiguousarray(src.indices, np.int32)
    indptr = np.ascontiguousarray(src.indptr, np.int64)
    n, d = (int(s) for s in src.shape)
    if indptr.shape != (n + 1,) or int(indptr[-1]) != data.size:
        raise ValueError(f"inconsistent CSR: indptr {indptr.shape} vs "
                         f"shape {(n, d)}, nnz {data.size}")
    if data.size and not (0 <= int(indices.min())
                          and int(indices.max()) < d):
        raise ValueError(
            f"CSR column ids outside [0, {d}): "
            f"[{int(indices.min())}, {int(indices.max())}]")
    return CSRMatrix(data, indices, indptr, (n, d))


def is_csr_like(obj) -> bool:
    """True when ``obj`` carries CSR arrays (used by store/solver ingest).

    Matches everything :func:`as_csr` accepts: ``CSRMatrix``, scipy-like
    attr objects, and the ``(data, indices, indptr, shape)`` tuple form —
    the tuple is recognized by its 1-D leading arrays and 2-tuple shape so
    a 4-row dense matrix passed as a list of lists is not misread as CSR.
    """
    if isinstance(obj, CSRMatrix) or \
            all(hasattr(obj, a) for a in ("data", "indices", "indptr",
                                          "shape")):
        return True
    if isinstance(obj, (tuple, list)) and len(obj) == 4:
        data, indices, indptr, shape = obj
        try:
            return (np.ndim(data) == 1 and np.ndim(indices) == 1 and
                    np.ndim(indptr) == 1 and len(shape) == 2)
        except TypeError:
            return False
    return False


def to_csr(X: np.ndarray) -> CSRMatrix:
    n, d = X.shape
    mask = X != 0
    counts = mask.sum(axis=1)
    indptr = np.zeros(n + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    cols = np.nonzero(mask)[1].astype(np.int32)
    data = X[mask].astype(np.float32)
    return CSRMatrix(data, cols, indptr, (n, d))


def to_ell(X: np.ndarray, K: int | None = None, lane: int = 128) -> ELLMatrix:
    """Pad rows to K nonzeros (default: max row nnz rounded up to ``lane``)."""
    n, d = X.shape
    mask = X != 0
    counts = mask.sum(axis=1)
    kmax = int(counts.max()) if n else 0
    if K is None:
        K = max(lane, -(-kmax // lane) * lane)
    if kmax > K:
        raise ValueError(f"row with {kmax} nnz exceeds K={K}")
    # stable argsort of ~mask packs each row's nonzero columns (in order)
    # into the first slots; the padding tail is masked to (val=0, col=0)
    order = np.argsort(~mask, axis=1, kind="stable")[:, :K]
    taken = np.take_along_axis(mask, order, axis=1)
    vals = np.take_along_axis(X, order, axis=1).astype(np.float32) * taken
    cols = (order * taken).astype(np.int32)
    return ELLMatrix(vals, cols, (n, d))


def csr_row_extent(csr: CSRMatrix) -> np.ndarray:
    """Per-row trailing-nonzero extent of the CSR->ELL packed layout: the
    in-row position of the last *nonzero* stored entry + 1 (0 for
    empty/all-zero rows). This — not ``row_nnz`` — is the smallest K a row
    survives truncation to, and it matches :func:`ell_row_extent` on the
    filled ELL buffer exactly, so host- and device-side adaptive-K
    compaction agree even when the CSR input carries explicitly stored
    zeros (thresholded matrices without ``eliminate_zeros()``)."""
    n = csr.shape[0]
    rows = np.repeat(np.arange(n), csr.row_nnz())
    pos = np.arange(csr.nnz, dtype=np.int64) - csr.indptr[rows]
    mask = csr.data != 0
    ext = np.zeros(n, np.int64)
    np.maximum.at(ext, rows[mask], pos[mask] + 1)
    return ext


def ell_row_extent(vals: np.ndarray) -> np.ndarray:
    """Per-row occupied-slot count of an ELL block: last nonzero slot + 1
    (0 for all-padding rows). ``to_ell`` packs nonzeros into a prefix, so
    this is the smallest K each row survives truncation to — what adaptive
    K recompaction measures on the surviving rows."""
    nz = vals != 0
    K = vals.shape[1]
    return np.where(nz.any(axis=1), K - np.argmax(nz[:, ::-1], axis=1),
                    0).astype(np.int64)


def round_lanes(k: int, lane: int) -> int:
    """Round a nonzero budget up to a whole number of TPU lanes (min 1)."""
    return max(lane, -(-int(k) // lane) * lane)


def bucket_lanes(k: int, lane: int, cap: "int | None" = None) -> int:
    """Lane-round ``k``, then round up to a power-of-two number of lanes.

    Adaptive K makes the ELL lane budget a fresh trace dimension at every
    physical compaction; bucketing to {1, 2, 4, ...} lanes bounds the jit
    cache to O(log(K_max / lane)) distinct entries per runner instead of
    one per compaction. ``cap`` (the store-wide K) keeps the first buffer
    from over-padding past what ingest ever needs.
    """
    lanes = -(-round_lanes(k, lane) // lane)
    k = lane * (1 << (lanes - 1).bit_length())
    if cap is not None:
        k = min(k, round_lanes(cap, lane))
    return k


def csr_space_report(X: np.ndarray) -> dict:
    """Fig. 1b: memory conserved by sparse formats vs dense."""
    dense = X.nbytes
    csr = to_csr(X).memory_bytes()
    ell = to_ell(X).memory_bytes()
    return {
        "dense_bytes": dense,
        "csr_bytes": csr,
        "ell_bytes": ell,
        "csr_saving_pct": 100.0 * (1 - csr / dense),
        "ell_saving_pct": 100.0 * (1 - ell / dense),
        "density": float(np.count_nonzero(X)) / X.size,
    }
