"""Data substrate: synthetic dataset generators (paper Table 2 stand-ins),
sparse CSR/block-ELL formats, deterministic LM token pipeline."""
from repro.data.synthetic import (SPECS, DatasetSpec, make, make_sparse,
                                  make_repeat_heavy, density)
from repro.data.sparse import (CSRMatrix, ELLMatrix, as_csr, is_csr_like,
                               to_csr, to_ell, ell_row_extent, round_lanes,
                               bucket_lanes, csr_space_report)
from repro.data.tokens import TokenPipeline
