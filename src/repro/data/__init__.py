"""Data substrate: synthetic dataset generators (paper Table 2 stand-ins),
sparse CSR/block-ELL formats, deterministic LM token pipeline."""
from repro.data.synthetic import SPECS, DatasetSpec, make, make_sparse, density
from repro.data.sparse import CSRMatrix, ELLMatrix, to_csr, to_ell, csr_space_report
from repro.data.tokens import TokenPipeline
