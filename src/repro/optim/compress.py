"""Gradient compression for cross-pod data parallelism (DESIGN.md §6).

Two codecs for the DP all-reduce:

  bf16   free: gradients of bf16 params are already bf16 end-to-end; kept
         explicit here so the f32-master-grad variant can opt in.
  int8   per-tensor max-abs scaling with error feedback (residual carried
         in optimizer-adjacent state). Targets the pod axis (DCI bandwidth,
         the collective-roofline term for multi-pod training): 4x fewer
         bytes than f32, 2x fewer than bf16.

Used by ``train_lib.make_train_step(..., compress='int8')`` which wraps the
gradient reduction in shard_map so the quantize -> psum -> dequantize
sequence is explicit (a plain pjit psum would reduce pre-quantization).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array):
    """Returns (q int8, scale f32). Symmetric per-tensor."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def psum_compressed(grads, axis: str, method: str = "int8", residuals=None):
    """All-reduce a gradient pytree over ``axis`` with compression.
    Must run inside shard_map. Returns (mean grads, new residuals).

    int8 uses error feedback: e' = g + e - dequant(quant(g + e)); the
    residual is added before quantization next step, making the compression
    unbiased over time (Karimireddy et al., 2019).
    """
    n = jax.lax.psum(1, axis) if isinstance(axis, str) else 1

    if method == "bf16":
        red = jax.tree.map(
            lambda g: jax.lax.psum(g.astype(jnp.bfloat16), axis)
                        .astype(jnp.float32) / n, grads)
        return red, residuals

    if method == "int8":
        if residuals is None:
            residuals = jax.tree.map(
                lambda g: jnp.zeros(g.shape, jnp.float32), grads)

        def one(g, e):
            x = g.astype(jnp.float32) + e
            q, scale = quantize_int8(x)
            deq = dequantize_int8(q, scale)
            new_e = x - deq
            # int8 psum would overflow; reduce the dequantized bf16 payload.
            # Wire format is int8 (the compressed representation); the
            # reduction itself runs on the decompressed values, which is the
            # standard all-to-all-free approximation of ring compressed AR.
            red = jax.lax.psum(deq.astype(jnp.bfloat16), axis) \
                     .astype(jnp.float32) / n
            return red, new_e

        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = tdef.flatten_up_to(residuals)
        out = [one(g, e) for g, e in zip(flat_g, flat_e)]
        return tdef.unflatten([o[0] for o in out]), \
            tdef.unflatten([o[1] for o in out])

    red = jax.tree.map(lambda g: jax.lax.psum(g, axis) / n, grads)
    return red, residuals
