"""AdamW built on raw pytrees (no optax in this environment).

Moments are fp32 regardless of parameter dtype; the update is computed in
fp32 and cast back. Because parameters are already FSDP-sharded over the
'data' axis (sharding.py), the moments inherit that sharding — ZeRO-1 falls
out of the sharding rules rather than being a separate mechanism.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(1.0, cfg.decay_steps - cfg.warmup_steps),
                    0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.minimum(warm, 1.0) * cos


def init(params: Any) -> dict:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(f32, params),
            "v": jax.tree.map(f32, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def update(cfg: AdamWConfig, grads: Any, state: dict, params: Any):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else jnp.float32(1.0)
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        step_ = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only, not norms/biases
            step_ = step_ + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * step_).astype(p.dtype)
        return newp, m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
