"""Checkpointing with elastic restore (DESIGN.md §6).

Format: one .npz per top-level state group (params / opt / extra) +
manifest.json (tree structure, shapes, dtypes, step, sha256 per file).
Save gathers to host (works from any sharding); restore device_puts onto
whatever mesh/sharding the *restarted* job uses — elastic rescale (N pods ->
M pods, or a different mesh shape entirely) is therefore the same code path
as plain restart. Async saves run on a daemon thread with an atomic
rename-into-place so a crash mid-save never corrupts the latest checkpoint.

SVM runs checkpoint (alpha, gamma, active, step) the same way — the epoch
driver (``repro.core.driver``) syncs its device-resident alpha/gamma
masters to host before each save, so the snapshot is complete even when
rows were dropped by device-side physical compaction (their drop-time
values live in the masters, not in the buffer), and an SMO optimization
restarts mid-training with bitwise-identical trajectory (the chunk runner
is deterministic given state; the row cache is deliberately not saved —
it is exact, so rebuilding it empty is trajectory-neutral).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import threading
from typing import Any, Optional

import numpy as np
import jax


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    flat = {}
    for path, leaf in leaves:
        key = "/".join(
            str(k.key) if hasattr(k, "key") else str(k.idx) for k in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "fiub":   # ml_dtypes (bf16/fp8): store as
            arr = arr.astype(np.float32)   # f32 (lossless up); restore casts
        flat[key] = arr
    return flat, jax.tree_util.tree_structure(tree)


def _sha(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for blk in iter(lambda: f.read(1 << 20), b""):
            h.update(blk)
    return h.hexdigest()


def save(directory: str, step: int, groups: dict[str, Any],
         extra: Optional[dict] = None, async_: bool = False):
    """groups: e.g. {'params': params, 'opt': opt_state}. Blocking unless
    ``async_`` (daemon thread; join via returned handle)."""
    def _do():
        tmp = tempfile.mkdtemp(dir=os.path.dirname(
            os.path.abspath(directory)) or ".")
        manifest = {"step": int(step), "groups": {}, "extra": extra or {}}
        for name, tree in groups.items():
            flat, _ = _flatten(tree)
            fn = os.path.join(tmp, f"{name}.npz")
            np.savez(fn, **flat)
            manifest["groups"][name] = {
                "file": f"{name}.npz", "sha256": _sha(fn),
                "keys": sorted(flat.keys()),
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.isdir(directory):
            shutil.rmtree(directory)
        os.replace(tmp, directory)

    if async_:
        t = threading.Thread(target=_do, daemon=True)
        t.start()
        return t
    _do()
    return None


def load_manifest(directory: str) -> dict:
    with open(os.path.join(directory, "manifest.json")) as f:
        return json.load(f)


def restore(directory: str, name: str, like: Any, shardings: Any = None,
            verify: bool = True) -> Any:
    """Restore group ``name`` into the structure of ``like`` (a pytree of
    arrays or ShapeDtypeStructs). ``shardings``: matching pytree of
    NamedShardings for the *current* mesh — this is where elastic resharding
    happens (host arrays -> device_put under the new layout)."""
    man = load_manifest(directory)
    info = man["groups"][name]
    fn = os.path.join(directory, info["file"])
    if verify:
        got = _sha(fn)
        if got != info["sha256"]:
            raise IOError(f"checkpoint corruption: {fn}: {got[:12]} != "
                          f"{info['sha256'][:12]}")
    data = np.load(fn)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    flat_sh = (jax.tree_util.tree_leaves(shardings)
               if shardings is not None else [None] * len(leaves))
    for (path, leaf), sh in zip(leaves, flat_sh):
        key = "/".join(
            str(k.key) if hasattr(k, "key") else str(k.idx) for k in path)
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None else
                   jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def latest_step(base: str) -> Optional[int]:
    """Scan ``base`` for step_XXXX directories; return the newest step."""
    if not os.path.isdir(base):
        return None
    steps = []
    for d in os.listdir(base):
        if d.startswith("step_") and os.path.exists(
                os.path.join(base, d, "manifest.json")):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None
