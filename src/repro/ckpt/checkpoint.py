"""Checkpointing with elastic restore (DESIGN.md §6).

Format: one .npz per top-level state group (params / opt / extra) +
manifest.json (tree structure, shapes, dtypes, step, sha256 per file AND
per array). Save gathers to host (works from any sharding); restore
device_puts onto whatever mesh/sharding the *restarted* job uses —
elastic rescale (N pods -> M pods, or a different mesh shape entirely) is
therefore the same code path as plain restart. Async saves run on a
daemon thread with an atomic rename-into-place so a crash mid-save never
corrupts the latest checkpoint.

Crash-safety contract
---------------------
A step directory is COMPLETE iff its manifest parses and every group
file it names exists with the recorded file-level sha256. Saves build
the whole step in a temp dir (manifest written last) and publish it with
``os.replace`` — a crash mid-save leaves a stray temp dir, never a torn
step. Overwriting an existing step renames the old dir aside first (no
rmtree-then-rename window where a half-deleted dir looks live); the only
crash window loses that ONE step cleanly, and readers fall back.
``latest_step`` ignores directories whose manifest is missing or
unparseable; ``complete_steps``/``step_complete`` add the content check
(file checksums) so resume can walk newest -> oldest past torn or
corrupted saves instead of crashing or silently loading garbage.
``with_retries`` is the bounded retry-with-backoff wrapper train drivers
put around checkpoint I/O (transient FS errors on shared storage).

SVM runs checkpoint (alpha, gamma, active, step) the same way — the epoch
driver (``repro.core.driver``) syncs its device-resident alpha/gamma
masters to host before each save, so the snapshot is complete even when
rows were dropped by device-side physical compaction (their drop-time
values live in the masters, not in the buffer), and an SMO optimization
restarts mid-training with bitwise-identical trajectory (the chunk runner
is deterministic given state; the row cache is deliberately not saved —
it is exact, so rebuilding it empty is trajectory-neutral). Because the
saved arrays are host (n,) masters — never device buffers — a checkpoint
carries NO trace of the mesh it was saved under: restore re-deals the
balanced buffer layout for whatever device count the restarted job has.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import threading
import time
import warnings
from typing import Any, Callable, Optional

import numpy as np
import jax


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    flat = {}
    for path, leaf in leaves:
        key = "/".join(
            str(k.key) if hasattr(k, "key") else str(k.idx) for k in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "fiub":   # ml_dtypes (bf16/fp8): store as
            arr = arr.astype(np.float32)   # f32 (lossless up); restore casts
        flat[key] = arr
    return flat, jax.tree_util.tree_structure(tree)


def _sha(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for blk in iter(lambda: f.read(1 << 20), b""):
            h.update(blk)
    return h.hexdigest()


def array_sha(arr: np.ndarray) -> str:
    """Content checksum of ONE array: dtype + shape + C-order bytes, so a
    reshaped or recast array never collides with the original."""
    a = np.ascontiguousarray(arr)
    h = hashlib.sha256()
    h.update(str(a.dtype).encode())
    h.update(str(a.shape).encode())
    h.update(a.tobytes())
    return h.hexdigest()


def with_retries(fn: Callable[[], Any], attempts: int = 3,
                 backoff: float = 0.05,
                 what: str = "checkpoint I/O") -> Any:
    """Bounded retry with exponential backoff for checkpoint I/O. Retries
    OSError/IOError only (transient FS faults); corruption and shape
    errors propagate immediately — retrying cannot fix those. Returns
    ``(result, retries_used)``."""
    last = None
    for i in range(max(1, attempts)):
        try:
            return fn(), i
        except OSError as e:        # noqa: PERF203 — the retry IS the point
            last = e
            if i + 1 < attempts:
                time.sleep(backoff * (2 ** i))
    raise IOError(f"{what} failed after {attempts} attempts") from last


def save(directory: str, step: int, groups: dict[str, Any],
         extra: Optional[dict] = None, async_: bool = False):
    """groups: e.g. {'params': params, 'opt': opt_state}. Blocking unless
    ``async_`` (daemon thread; join via returned handle)."""
    def _do():
        parent = os.path.dirname(os.path.abspath(directory)) or "."
        os.makedirs(parent, exist_ok=True)
        tmp = tempfile.mkdtemp(dir=parent)
        manifest = {"step": int(step), "groups": {}, "extra": extra or {}}
        for name, tree in groups.items():
            flat, _ = _flatten(tree)
            fn = os.path.join(tmp, f"{name}.npz")
            np.savez(fn, **flat)
            manifest["groups"][name] = {
                "file": f"{name}.npz", "sha256": _sha(fn),
                "keys": sorted(flat.keys()),
                # per-array content checksums: restore verifies each array
                # it actually loads, so a bit flip inside a zip member is
                # caught even when the file-level sha was skipped
                "array_sha256": {k: array_sha(v) for k, v in flat.items()},
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.isdir(directory):
            # rename the old step aside BEFORE publishing the new one:
            # os.replace cannot atomically swap non-empty dirs, and the old
            # rmtree-then-rename left a window where a crash exposed a
            # half-deleted directory as the "latest" checkpoint
            trash = tempfile.mkdtemp(dir=parent)
            old = os.path.join(trash, "old")
            os.replace(directory, old)
            os.replace(tmp, directory)
            shutil.rmtree(trash, ignore_errors=True)
        else:
            os.replace(tmp, directory)

    if async_:
        t = threading.Thread(target=_do, daemon=True)
        t.start()
        return t
    _do()
    return None


def load_manifest(directory: str) -> dict:
    with open(os.path.join(directory, "manifest.json")) as f:
        return json.load(f)


def restore(directory: str, name: str, like: Any, shardings: Any = None,
            verify: bool = True) -> Any:
    """Restore group ``name`` into the structure of ``like`` (a pytree of
    arrays or ShapeDtypeStructs). ``shardings``: matching pytree of
    NamedShardings for the *current* mesh — this is where elastic resharding
    happens (host arrays -> device_put under the new layout)."""
    man = load_manifest(directory)
    info = man["groups"][name]
    fn = os.path.join(directory, info["file"])
    if verify:
        got = _sha(fn)
        if got != info["sha256"]:
            raise IOError(f"checkpoint corruption: {fn}: {got[:12]} != "
                          f"{info['sha256'][:12]}")
    data = np.load(fn)
    arr_sha = info.get("array_sha256", {})
    leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    flat_sh = (jax.tree_util.tree_leaves(shardings)
               if shardings is not None else [None] * len(leaves))
    for (path, leaf), sh in zip(leaves, flat_sh):
        key = "/".join(
            str(k.key) if hasattr(k, "key") else str(k.idx) for k in path)
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        if verify and key in arr_sha and array_sha(arr) != arr_sha[key]:
            raise IOError(f"checkpoint corruption: {fn}:{key} content "
                          "checksum mismatch")
        arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None else
                   jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def step_complete(directory: str) -> bool:
    """True iff the step dir is a COMPLETE save: manifest parses and every
    group file exists with its recorded file-level sha256. This is the
    cheap(ish) gate resume uses to skip torn/corrupt steps; per-array
    checksums are re-verified at restore() time for the arrays loaded."""
    try:
        man = load_manifest(directory)
        for info in man["groups"].values():
            fn = os.path.join(directory, info["file"])
            if _sha(fn) != info["sha256"]:
                return False
    except (OSError, ValueError, KeyError):
        return False
    return True


def _step_dirs(base: str) -> list[tuple[int, str]]:
    out = []
    if not os.path.isdir(base):
        return out
    for d in os.listdir(base):
        if d.startswith("step_"):
            try:
                out.append((int(d.split("_")[1]), os.path.join(base, d)))
            except ValueError:
                continue
    return sorted(out)


def complete_steps(base: str) -> list[int]:
    """All COMPLETE steps under ``base``, ascending. Torn saves (missing /
    unparseable manifest) and corrupt ones (file checksum mismatch) are
    skipped — resume walks this list from the back."""
    return [s for s, d in _step_dirs(base) if step_complete(d)]


def latest_step(base: str) -> Optional[int]:
    """Scan ``base`` for step_XXXX directories; return the newest step
    whose manifest parses (torn saves — no/invalid manifest — are
    ignored). Content checksums are NOT verified here; use
    ``complete_steps`` / ``step_complete`` when corruption fallback
    matters."""
    steps = []
    for s, d in _step_dirs(base):
        try:
            load_manifest(d)
        except (OSError, ValueError):
            warnings.warn(f"skipping torn checkpoint dir {d}")
            continue
        steps.append(s)
    return max(steps) if steps else None
