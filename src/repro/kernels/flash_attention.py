"""Pallas TPU kernel: causal flash attention (forward) with GQA.

Canonical FA tiling: grid (batch*q_heads, Lq/bq, Lk/bk) with the kv axis
minor-most ("arbitrary" = sequential on core), online-softmax running
(m, l, acc) in VMEM scratch that persists across kv grid steps. GQA is
handled in the BlockSpec index maps — the kv block for q-head h comes from
kv-head h // (H/Hkv), so grouped heads share K/V HBM reads.

Used by LM train/prefill steps when ``use_pallas=True``; the dry-run and the
numerics tests use ``ref.mha`` (fp32 softmax oracle). Backward falls back to
the oracle via custom_vjp (recompute; documented in DESIGN.md §7).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, block_q: int, block_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: kv block [ki*bk, ki*bk+bk) intersects rows <= qi*bq+bq-1
    should_run = (ki * block_k <= qi * block_q + block_q - 1) if causal \
        else (ki >= 0)

    @pl.when(should_run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, dh)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, dh)
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, dh)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            cols = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(rows >= cols, s, _NEG_INF)

        m_prev = m_scr[...]                          # (bq, 1)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_cur)                       # (bq, bk)
        corr = jnp.exp(m_prev - m_cur)               # (bq, 1)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_cur

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "block_q", "block_k",
                                    "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 512,
                    block_k: int = 512, interpret: bool = False) -> jax.Array:
    """q: (B, H, Lq, Dh); k/v: (B, Hkv, Lk, Dh). Returns (B, H, Lq, Dh)."""
    B, H, Lq, Dh = q.shape
    Hkv, Lk = k.shape[1], k.shape[2]
    group = H // Hkv
    block_q = min(block_q, Lq)
    block_k = min(block_k, Lk)
    assert Lq % block_q == 0 and Lk % block_k == 0
    scale = Dh ** -0.5
    grid = (B * H, Lq // block_q, Lk // block_k)

    kernel = functools.partial(_fa_kernel, scale=scale, causal=causal,
                               block_q=block_q, block_k=block_k)
    from jax.experimental.pallas import tpu as pltpu
    # VMEM scratch works both compiled and in interpret mode on every JAX we
    # support; the compiler-params class was renamed across 0.4 -> 0.5
    # (TPUCompilerParams -> CompilerParams).
    scratch = [pltpu.VMEM((block_q, 1), jnp.float32),
               pltpu.VMEM((block_q, 1), jnp.float32),
               pltpu.VMEM((block_q, Dh), jnp.float32)]
    cp_cls = getattr(pltpu, "CompilerParams",
                     getattr(pltpu, "TPUCompilerParams", None))
    params = {} if (interpret or cp_cls is None) else dict(
        compiler_params=cp_cls(
            dimension_semantics=("parallel", "parallel", "arbitrary")))

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, Dh),
                         lambda bh, qi, ki: (bh // H, bh % H, qi, 0)),
            pl.BlockSpec((1, 1, block_k, Dh),
                         lambda bh, qi, ki: (bh // H, (bh % H) // group, ki, 0)),
            pl.BlockSpec((1, 1, block_k, Dh),
                         lambda bh, qi, ki: (bh // H, (bh % H) // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, Dh),
                               lambda bh, qi, ki: (bh // H, bh % H, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Lq, Dh), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
        **params,
    )(q, k, v)
