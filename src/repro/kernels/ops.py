"""jit'd public wrappers for the Pallas kernels.

Responsibilities: pad shapes to TPU-friendly multiples, pick block sizes that
fit VMEM, auto-select interpret mode off-TPU, and fall back to the ref.py
oracles where a kernel doesn't apply (non-RBF kernels, tiny buffers).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import gamma_update as _gu
from repro.kernels import rbf_row as _rr
from repro.kernels import ref
from repro.kernels import sparse_ell as _se

_VMEM_BUDGET = 12 * 1024 * 1024  # leave headroom of the ~16 MiB per core


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pick_block_m(n: int, d: int) -> int:
    """Largest power-of-two block (<=2048, >=128) dividing n whose X tile
    fits the VMEM budget."""
    bm = 2048
    while bm > 128 and (n % bm != 0 or bm * max(d, 128) * 4 > _VMEM_BUDGET):
        bm //= 2
    return bm if n % bm == 0 else 0


def _pad_cols(a: jax.Array, mult: int = 128) -> jax.Array:
    pad = (-a.shape[-1]) % mult
    if pad:
        a = jnp.pad(a, [(0, 0)] * (a.ndim - 1) + [(0, pad)])
    return a


def kernel_rows2(kernel: str, X: jax.Array, sq_norms: jax.Array,
                 z2: jax.Array, inv_2s2) -> jax.Array:
    """(N, 2) kernel rows; Pallas for RBF, oracle otherwise."""
    n, d = X.shape
    bm = _pick_block_m(n, d)
    if kernel != "rbf" or bm == 0:
        if kernel == "rbf":
            return ref.kernel_rows2(X, sq_norms, z2, inv_2s2)
        from repro.core import kernel_fns
        return kernel_fns.get_rows2(kernel)(X, sq_norms, z2, inv_2s2)
    out = _rr.rbf_rows2(_pad_cols(X), sq_norms, _pad_cols(z2),
                        jnp.asarray(inv_2s2, jnp.float32),
                        block_m=bm, interpret=_interpret())
    return out.T


def fused_gamma_update(kernel: str, X: jax.Array, sq_norms: jax.Array,
                       gamma: jax.Array, z2: jax.Array, coef2: jax.Array,
                       inv_2s2) -> jax.Array:
    """gamma + coef2[0]*K(z_up, X) + coef2[1]*K(z_low, X), one HBM pass."""
    n, d = X.shape
    bm = _pick_block_m(n, d)
    if kernel != "rbf" or bm == 0:
        if kernel == "rbf":
            return ref.gamma_update(X, sq_norms, gamma, z2, coef2, inv_2s2)
        from repro.core import kernel_fns
        rows = kernel_fns.get_rows2(kernel)(X, sq_norms, z2, inv_2s2)
        return gamma + rows @ coef2
    return _gu.gamma_update(_pad_cols(X), sq_norms, gamma, _pad_cols(z2),
                            coef2, jnp.asarray(inv_2s2, jnp.float32),
                            block_m=bm, interpret=_interpret())


def gamma_from_rows(gamma: jax.Array, rows: jax.Array,
                    coef2: jax.Array) -> jax.Array:
    """Eq. 6 epilogue from already-produced kernel rows: gamma + rows@coef2.

    This is the Pallas-path consumer of the row-provider layer's output —
    when the solver's LRU row cache serves a hit, the fused
    ``(ell_)fused_gamma_update`` kernels (which recompute rows from the
    sample storage) are bypassed and only this O(M) FMA runs. It is a plain
    XLA fusion on purpose: the (M, 2) rows are already in HBM/registers and
    a dedicated kernel would add launch overhead for a memory-bound FMA.
    Kept bit-identical to the jnp providers' ``gamma + rows @ coef2`` so
    cache hits and misses compose exactly.
    """
    return gamma + rows @ coef2


def _pick_block_q(b: int, d: int, lo: int = 8, hi: int = 128) -> int:
    """Query-microbatch tile for the accumulate kernels: largest power of
    two in [lo, hi] dividing b whose dense query tile fits a slice of the
    VMEM budget (the SV tile is the big tenant). Serve buckets are powers
    of two (core/serve.py), so this normally returns min(b, hi)."""
    bq = hi
    while bq > lo and (b % bq != 0 or bq * max(d, 128) * 4 > _VMEM_BUDGET // 4):
        bq //= 2
    return bq if b % bq == 0 else 0


def rbf_accumulate(X: jax.Array, sq_norms: jax.Array, coef: jax.Array,
                   Z: jax.Array, inv_2s2) -> jax.Array:
    """(B,) fused decision partials sum_i coef[i]*K(Z_j, X_i); Pallas when
    the SV/query axes divide a block grid, ref oracle otherwise."""
    m, d = X.shape
    bm = _pick_block_m(m, d)
    bq = _pick_block_q(Z.shape[0], d)
    if bm == 0 or bq == 0:
        return ref.rbf_accumulate(X, sq_norms, coef, Z, inv_2s2)
    return _rr.rbf_accumulate(_pad_cols(X), sq_norms, coef, _pad_cols(Z),
                              jnp.asarray(inv_2s2, jnp.float32),
                              block_m=bm, block_q=bq,
                              interpret=_interpret())


def ell_rbf_accumulate(vals: jax.Array, cols: jax.Array, sq_norms: jax.Array,
                       coef: jax.Array, Z: jax.Array, inv_2s2) -> jax.Array:
    """(B,) fused decision partials over block-ELL SVs; the in-kernel query
    loop unrolls bq gathers, so the query tile is capped low (the grid's
    outer axis picks up the rest of the microbatch)."""
    m, K = vals.shape
    bm = _pick_ell_block_m(m, K)
    bq = _pick_block_q(Z.shape[0], Z.shape[1], lo=4, hi=8)
    if bm == 0 or bq == 0:
        from repro.core import kernel_fns
        return kernel_fns.ell_cross_kernel("rbf", Z, vals, cols, sq_norms,
                                           inv_2s2) @ coef
    return _se_accumulate(vals, cols, sq_norms, coef, Z, inv_2s2, bm, bq)


def _se_accumulate(vals, cols, sq_norms, coef, Z, inv_2s2, bm, bq):
    return _rr.ell_rbf_accumulate(_pad_cols(vals), _pad_cols(cols), sq_norms,
                                  coef, Z,
                                  jnp.asarray(inv_2s2, jnp.float32),
                                  block_m=bm, block_q=bq,
                                  interpret=_interpret())


def _pick_ell_block_m(n: int, K: int = 128) -> int:
    """Largest block (<=512, >=64) dividing n whose (vals, cols) tiles fit
    the VMEM budget at lane budget K. Adaptive-K recompaction makes K a
    *live* trace dimension — it changes across compactions — so the block
    choice must scale with it or large-K ingest buffers blow VMEM. Each
    (bm, K) bucket gets its own Pallas specialization; the driver buckets K
    to power-of-two lanes so this stays O(log K_max) entries, not one per
    compaction."""
    bm = 512
    while bm > 64 and (n % bm != 0 or bm * max(K, 128) * 8 > _VMEM_BUDGET):
        bm //= 2
    return bm if n % bm == 0 and bm * max(K, 128) * 8 <= _VMEM_BUDGET else 0


def ell_kernel_row(vals: jax.Array, cols: jax.Array, sq_norms: jax.Array,
                   z: jax.Array, inv_2s2) -> jax.Array:
    bm = _pick_ell_block_m(*vals.shape)
    if bm == 0:
        return ref.ell_kernel_row(vals, cols, sq_norms, z, inv_2s2)
    return _se.ell_kernel_row(_pad_cols(vals), _pad_cols(cols), sq_norms, z,
                              jnp.asarray(inv_2s2, jnp.float32),
                              block_m=bm, interpret=_interpret())


def ell_kernel_rows2(vals: jax.Array, cols: jax.Array, sq_norms: jax.Array,
                     z2: jax.Array, inv_2s2) -> jax.Array:
    """(N, 2) RBF rows on ELL storage; Pallas when N divides a block."""
    bm = _pick_ell_block_m(*vals.shape)
    if bm == 0:
        return ref.ell_kernel_rows2(vals, cols, sq_norms, z2, inv_2s2)
    return _se.ell_kernel_rows2(_pad_cols(vals), _pad_cols(cols), sq_norms,
                                _pad_cols(z2),
                                jnp.asarray(inv_2s2, jnp.float32),
                                block_m=bm, interpret=_interpret())


def ell_fused_gamma_update(kernel: str, vals: jax.Array, cols: jax.Array,
                           sq_norms: jax.Array, gamma: jax.Array,
                           z2: jax.Array, coef2: jax.Array,
                           inv_2s2) -> jax.Array:
    """Fused Eq. 6 on ELL storage; oracle fallback off-grid / non-RBF."""
    bm = _pick_ell_block_m(*vals.shape)
    if kernel != "rbf" or bm == 0:
        if kernel == "rbf":
            return ref.ell_gamma_update(vals, cols, sq_norms, gamma, z2,
                                        coef2, inv_2s2)
        from repro.core import kernel_fns
        rows = kernel_fns.get_ell_rows2(kernel)(vals, cols, sq_norms, z2,
                                                inv_2s2)
        return gamma + rows @ coef2
    return _se.ell_gamma_update(_pad_cols(vals), _pad_cols(cols), sq_norms,
                                gamma, _pad_cols(z2), coef2,
                                jnp.asarray(inv_2s2, jnp.float32),
                                block_m=bm, interpret=_interpret())


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def flash_attention(q, k, v, causal: bool = True):
    """(B, H, L, Dh) causal attention; Pallas fwd, oracle-recompute bwd."""
    return _fa.flash_attention(q, k, v, causal=causal,
                               interpret=_interpret())


def _fa_ref(q, k, v, causal):
    # ref.mha uses (B, L, H, D) layout
    o = ref.mha(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                v.transpose(0, 2, 1, 3), causal=causal)
    return o.transpose(0, 2, 1, 3)


def _fa_fwd(q, k, v, causal):
    return flash_attention(q, k, v, causal), (q, k, v)


def _fa_bwd(causal, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q, k, v: _fa_ref(q, k, v, causal), q, k, v)
    return vjp(g)


flash_attention.defvjp(_fa_fwd, _fa_bwd)
