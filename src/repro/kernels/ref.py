"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are the semantics each kernel must reproduce; tests sweep shapes and
dtypes and assert allclose against these. They are also the path used by the
multi-pod dry-run so ``cost_analysis()`` sees the real FLOPs (a Pallas custom
call would hide them from the XLA cost model — see EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def kernel_rows2(X: jax.Array, sq_norms: jax.Array, z2: jax.Array,
                 inv_2s2: float) -> jax.Array:
    """RBF rows for two query points: out[i, j] = K(z2[j], X[i]). (N, 2)."""
    prods = X @ z2.T
    zn = jnp.sum(z2 * z2, axis=-1)
    d2 = sq_norms[:, None] - 2.0 * prods + zn[None, :]
    return jnp.exp(-jnp.maximum(d2, 0.0) * inv_2s2)


def gamma_update(X: jax.Array, sq_norms: jax.Array, gamma: jax.Array,
                 z2: jax.Array, coef2: jax.Array, inv_2s2: float) -> jax.Array:
    """Fused Eq. 6: gamma + coef2[0]*K(z_up, X) + coef2[1]*K(z_low, X)."""
    k = kernel_rows2(X, sq_norms, z2, inv_2s2)
    return gamma + k @ coef2


def ell_kernel_row(vals: jax.Array, cols: jax.Array, sq_norms: jax.Array,
                   z: jax.Array, inv_2s2: float) -> jax.Array:
    """RBF row against block-ELL samples.

    vals: (N, K) padded nonzero values, cols: (N, K) their column ids
    (padding: val 0 / col 0), sq_norms: (N,) = sum(vals^2), z: (d,) dense.
    out[i] = exp(-(||x_i||^2 - 2 <x_i, z> + ||z||^2) * inv_2s2).
    """
    zg = jnp.take(z, cols, axis=0)               # (N, K) gather
    dots = jnp.sum(vals * zg, axis=-1)           # (N,)
    d2 = sq_norms - 2.0 * dots + jnp.dot(z, z)
    return jnp.exp(-jnp.maximum(d2, 0.0) * inv_2s2)


def ell_kernel_rows2(vals: jax.Array, cols: jax.Array, sq_norms: jax.Array,
                     z2: jax.Array, inv_2s2: float) -> jax.Array:
    """RBF rows for two queries against block-ELL samples. (N, 2)."""
    zg = jnp.take(z2, cols, axis=1)              # (2, N, K)
    dots = jnp.einsum("nk,jnk->nj", vals, zg)    # (N, 2)
    zn = jnp.sum(z2 * z2, axis=-1)
    d2 = sq_norms[:, None] - 2.0 * dots + zn[None, :]
    return jnp.exp(-jnp.maximum(d2, 0.0) * inv_2s2)


def ell_gamma_update(vals: jax.Array, cols: jax.Array, sq_norms: jax.Array,
                     gamma: jax.Array, z2: jax.Array, coef2: jax.Array,
                     inv_2s2: float) -> jax.Array:
    """Fused Eq. 6 on ELL storage (oracle for the Pallas kernel)."""
    k = ell_kernel_rows2(vals, cols, sq_norms, z2, inv_2s2)
    return gamma + k @ coef2


def rbf_accumulate(X: jax.Array, sq_norms: jax.Array, coef: jax.Array,
                   Z: jax.Array, inv_2s2: float) -> jax.Array:
    """Fused serve-time decision sum: out[j] = sum_i coef[i] * K(Z[j], X[i]).

    The oracle for the Pallas ``rbf_accumulate`` tile kernel — the (B, M)
    kernel matrix is never materialized by the kernel, only by this
    reference. Padding SV rows carry coef 0, so they contribute exactly 0
    whatever their (X, sq) content.
    """
    qn = jnp.sum(Z * Z, axis=-1)
    d2 = qn[:, None] - 2.0 * (Z @ X.T) + sq_norms[None, :]
    return jnp.exp(-jnp.maximum(d2, 0.0) * inv_2s2) @ coef


def ell_rbf_accumulate(vals: jax.Array, cols: jax.Array, sq_norms: jax.Array,
                       coef: jax.Array, Z: jax.Array,
                       inv_2s2: float) -> jax.Array:
    """Serve-time decision sum against block-ELL SVs (oracle; materializes
    the (B, M, K) gather — tests only, the blocked paths never do)."""
    zg = jnp.take(Z, cols, axis=1)                    # (B, M, K)
    dots = jnp.einsum("mk,jmk->jm", vals, zg)         # (B, M)
    qn = jnp.sum(Z * Z, axis=-1)
    d2 = qn[:, None] - 2.0 * dots + sq_norms[None, :]
    return jnp.exp(-jnp.maximum(d2, 0.0) * inv_2s2) @ coef


def mha(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True,
        scale: float | None = None) -> jax.Array:
    """Reference attention. q: (B, Lq, H, Dh), k/v: (B, Lk, Hkv, Dh) with
    H a multiple of Hkv (GQA). Returns (B, Lq, H, Dh). fp32 softmax."""
    B, Lq, H, Dh = q.shape
    Hkv = k.shape[2]
    if scale is None:
        scale = Dh ** -0.5
    group = H // Hkv
    qg = q.reshape(B, Lq, Hkv, group, Dh)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        Lk = k.shape[1]
        # decode-style offset: query i attends to keys <= i + (Lk - Lq)
        mask = (jnp.arange(Lq)[:, None] + (Lk - Lq)) >= jnp.arange(Lk)[None, :]
        logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Lq, H, Dh).astype(q.dtype)
