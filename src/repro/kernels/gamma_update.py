"""Pallas TPU kernel: fused dual-row gamma update (Eq. 6 in one HBM pass).

gamma_i += c_up * K(z_up, X_i) + c_low * K(z_low, X_i)

Beyond-paper fusion (DESIGN.md §2): the paper computes the two kernel rows
and then updates gamma (two passes over the active set per iteration in the
jnp/XLA formulation: one for the (N,2) GEMM output, one for the FMA). This
kernel reads each X tile and the gamma tile once, keeps the (2, bm) kernel
rows in VMEM/VREGs, and writes only the updated gamma tile — per-iteration
HBM traffic drops from N*(d+2)+2N reads + N writes to N*(d+1) reads +
N writes, i.e. essentially the X stream alone, which is the memory-roofline
floor for a no-cache SMO iteration.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gamma_kernel(x_ref, sq_ref, g_ref, z_ref, coef_ref, inv_ref, out_ref):
    x = x_ref[...]                                   # (bm, d)
    z = z_ref[...]                                   # (2, d)
    prods = jax.lax.dot_general(
        z, x, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # (2, bm)
    zn = jnp.sum(z * z, axis=1)
    d2 = sq_ref[...] - 2.0 * prods + zn[:, None]
    k = jnp.exp(-jnp.maximum(d2, 0.0) * inv_ref[0, 0])   # (2, bm)
    c = coef_ref[...]                                # (2, 1)
    out_ref[...] = g_ref[...] + jnp.sum(k * c, axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def gamma_update(X: jax.Array, sq_norms: jax.Array, gamma: jax.Array,
                 z2: jax.Array, coef2: jax.Array, inv_2s2: jax.Array, *,
                 block_m: int = 1024, interpret: bool = False) -> jax.Array:
    """Returns updated gamma (N,). Caller pads N to block_m, d to 128."""
    n, d = X.shape
    assert n % block_m == 0, (n, block_m)
    grid = (n // block_m,)
    out = pl.pallas_call(
        _gamma_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i: (i, 0)),
            pl.BlockSpec((1, block_m), lambda i: (0, i)),
            pl.BlockSpec((1, block_m), lambda i: (0, i)),
            pl.BlockSpec((2, d), lambda i: (0, 0)),
            pl.BlockSpec((2, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_m), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        interpret=interpret,
    )(X, sq_norms.reshape(1, n), gamma.reshape(1, n), z2,
      coef2.reshape(2, 1), inv_2s2.reshape(1, 1))
    return out.reshape(n)
