"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel module pairs with a pure-jnp oracle in ``ref.py``; ``ops.py``
holds the jit'd public wrappers (padding, block-size choice, interpret-mode
fallback off-TPU).
"""
