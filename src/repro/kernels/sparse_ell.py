"""Pallas TPU kernel: RBF kernel row over block-ELL sparse samples.

TPU adaptation of the paper's CSR inner product (Alg. 2). The sequential
two-pointer merge-join is scalar control flow — hostile to the VPU — so we
re-block the data (DESIGN.md §2): every sample stores its nonzeros padded to
a fixed K (multiple of 128), as (vals, cols) pairs. The query z is dense in
VMEM; the kernel gathers z[cols] lane-wise and FMAs against vals. Padding
slots hold (val=0, col=0) and contribute exactly 0.

Space: 2 * K * 4 bytes/sample vs d * 4 dense — a win whenever density < d/2K,
preserving the paper's CSR memory argument (Fig. 1b) in vector-friendly form.
Mosaic requirement: 32-bit VMEM vector gather (available on v4+; validated
here in interpret mode).

K is a live trace dimension: adaptive-K recompaction (core/dataplane) hands
these kernels a fresh, smaller lane budget as the active set contracts, so
each jitted wrapper specializes per (block_m, K) bucket. On real TPUs K must
be a multiple of 128 (ops.py lane-pads before calling; Mosaic tiling assumes
it — interpret mode tolerates any K, which the kernel unit tests exercise),
and the driver buckets K to power-of-two lanes so the cache stays
O(log K_max).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ell_kernel(vals_ref, cols_ref, sq_ref, z_ref, zz_inv_ref, out_ref):
    vals = vals_ref[...]                             # (bm, K)
    cols = cols_ref[...]                             # (bm, K) int32
    z = z_ref[...]                                   # (1, d)
    zg = jnp.take(z[0], cols, axis=0)                # (bm, K) vector gather
    dots = jnp.sum(vals * zg, axis=1)                # (bm,)
    zz = zz_inv_ref[0, 0]
    inv = zz_inv_ref[0, 1]
    d2 = sq_ref[...] - 2.0 * dots[None, :] + zz      # (1, bm)
    out_ref[...] = jnp.exp(-jnp.maximum(d2, 0.0) * inv)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def ell_kernel_row(vals: jax.Array, cols: jax.Array, sq_norms: jax.Array,
                   z: jax.Array, inv_2s2: jax.Array, *, block_m: int = 512,
                   interpret: bool = False) -> jax.Array:
    """out[i] = K_rbf(z, x_i) for block-ELL samples. Returns (N,)."""
    n, K = vals.shape
    d = z.shape[0]
    assert n % block_m == 0, (n, block_m)
    zz_inv = jnp.stack([jnp.dot(z, z), inv_2s2.reshape(())]).reshape(1, 2)
    out = pl.pallas_call(
        _ell_kernel,
        grid=(n // block_m,),
        in_specs=[
            pl.BlockSpec((block_m, K), lambda i: (i, 0)),
            pl.BlockSpec((block_m, K), lambda i: (i, 0)),
            pl.BlockSpec((1, block_m), lambda i: (0, i)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_m), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        interpret=interpret,
    )(vals, cols, sq_norms.reshape(1, n), z.reshape(1, d), zz_inv)
    return out.reshape(n)


def _ell_rows2_body(vals_ref, cols_ref, sq_ref, z2_ref, inv_ref):
    """Shared compute: the (2, bm) RBF rows for one ELL tile."""
    vals = vals_ref[...]                             # (bm, K)
    cols = cols_ref[...]                             # (bm, K) int32
    z2 = z2_ref[...]                                 # (2, d)
    g0 = jnp.take(z2[0], cols, axis=0)               # (bm, K) vector gather
    g1 = jnp.take(z2[1], cols, axis=0)
    dots = jnp.stack([jnp.sum(vals * g0, axis=1),
                      jnp.sum(vals * g1, axis=1)])   # (2, bm)
    zn = jnp.sum(z2 * z2, axis=1)                    # (2,)
    d2 = sq_ref[...] - 2.0 * dots + zn[:, None]      # (2, bm)
    return jnp.exp(-jnp.maximum(d2, 0.0) * inv_ref[0, 0])


def _ell_rows2_kernel(vals_ref, cols_ref, sq_ref, z2_ref, inv_ref, out_ref):
    out_ref[...] = _ell_rows2_body(vals_ref, cols_ref, sq_ref, z2_ref, inv_ref)


def _ell_gamma_kernel(vals_ref, cols_ref, sq_ref, g_ref, z2_ref, coef_ref,
                      inv_ref, out_ref):
    k = _ell_rows2_body(vals_ref, cols_ref, sq_ref, z2_ref, inv_ref)
    out_ref[...] = g_ref[...] + jnp.sum(k * coef_ref[...], axis=0,
                                        keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def ell_kernel_rows2(vals: jax.Array, cols: jax.Array, sq_norms: jax.Array,
                     z2: jax.Array, inv_2s2: jax.Array, *, block_m: int = 512,
                     interpret: bool = False) -> jax.Array:
    """Fused two-row RBF over block-ELL samples: one (vals, cols) stream for
    both working-set rows. Returns (N, 2) = K([z_up; z_low], X).T."""
    n, K = vals.shape
    d = z2.shape[1]
    assert n % block_m == 0, (n, block_m)
    out = pl.pallas_call(
        _ell_rows2_kernel,
        grid=(n // block_m,),
        in_specs=[
            pl.BlockSpec((block_m, K), lambda i: (i, 0)),
            pl.BlockSpec((block_m, K), lambda i: (i, 0)),
            pl.BlockSpec((1, block_m), lambda i: (0, i)),
            pl.BlockSpec((2, d), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((2, block_m), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((2, n), jnp.float32),
        interpret=interpret,
    )(vals, cols, sq_norms.reshape(1, n), z2, inv_2s2.reshape(1, 1))
    return out.T


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def ell_gamma_update(vals: jax.Array, cols: jax.Array, sq_norms: jax.Array,
                     gamma: jax.Array, z2: jax.Array, coef2: jax.Array,
                     inv_2s2: jax.Array, *, block_m: int = 512,
                     interpret: bool = False) -> jax.Array:
    """Fused Eq. 6 on ELL storage: gamma += c_up*K(z_up, X) + c_low*K(z_low, X)
    in one pass over the (vals, cols) stream. Returns (N,)."""
    n, K = vals.shape
    d = z2.shape[1]
    assert n % block_m == 0, (n, block_m)
    out = pl.pallas_call(
        _ell_gamma_kernel,
        grid=(n // block_m,),
        in_specs=[
            pl.BlockSpec((block_m, K), lambda i: (i, 0)),
            pl.BlockSpec((block_m, K), lambda i: (i, 0)),
            pl.BlockSpec((1, block_m), lambda i: (0, i)),
            pl.BlockSpec((1, block_m), lambda i: (0, i)),
            pl.BlockSpec((2, d), lambda i: (0, 0)),
            pl.BlockSpec((2, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_m), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        interpret=interpret,
    )(vals, cols, sq_norms.reshape(1, n), gamma.reshape(1, n), z2,
      coef2.reshape(2, 1), inv_2s2.reshape(1, 1))
    return out.reshape(n)
