"""Pallas TPU kernels: fused two-query RBF rows + the serve-time
RBF-accumulate.

``rbf_rows2`` — out[i, j] = exp(-(||X_i||^2 - 2 <X_i, z_j> + ||z_j||^2)
/ (2 sigma^2)), j in {up, low}: the per-iteration hot spot of SMO
(DESIGN.md §7).

TPU mapping: the contraction is laid out as z2 (2, d) x X_blk^T (d, bm) ->
(2, bm) so the *lane* dimension is the long sample axis (bm, a multiple of
128) and the MXU sees a well-shaped (pad-to-8, d) x (d, bm) matmul; the
exp runs on the VPU over the same (2, bm) tile. X streams HBM->VMEM once;
norms/γ tiles ride along as (1, bm) row vectors.

Grid: (N / bm,). VMEM per step ~ bm*d*4 bytes for the X tile (+ O(bm)) —
ops.py picks bm so this fits the ~16 MiB VMEM budget.

``rbf_accumulate`` / ``ell_rbf_accumulate`` — the inference-plane hot
spot (core/serve.py): decision partials f[j] = sum_i coef_i * K(z_j, x_i)
over a query microbatch Z (B, d) against the SV set, *without ever
materializing the (B, M) kernel matrix*. The SV axis rides the grid's
inner dimension; each step computes one (bm, bq) kernel tile and
immediately contracts it against the coef tile into the revisited (1, bq)
output block — the matmul epilogue IS the accumulation, so HBM traffic is
one pass over the SV tiles + O(B) for the output, never O(B * M). Queries
sit on the lane axis of the output (bq a multiple of 128 on real TPUs;
interpret mode tolerates any bq), samples on the lane axis of the kernel
tile contraction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rows2_kernel(x_ref, sq_ref, z_ref, inv_ref, out_ref):
    x = x_ref[...]                                   # (bm, d)
    z = z_ref[...]                                   # (2, d)
    # (2, d) x (bm, d)^T -> (2, bm): lane dim = bm
    prods = jax.lax.dot_general(
        z, x, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # (2, bm)
    zn = jnp.sum(z * z, axis=1)                      # (2,)
    d2 = sq_ref[...] - 2.0 * prods + zn[:, None]     # (2, bm) via (1,bm) bcast
    out_ref[...] = jnp.exp(-jnp.maximum(d2, 0.0) * inv_ref[0, 0])


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def rbf_rows2(X: jax.Array, sq_norms: jax.Array, z2: jax.Array,
              inv_2s2: jax.Array, *, block_m: int = 1024,
              interpret: bool = False) -> jax.Array:
    """Returns (2, N) kernel rows. Caller pads N to block_m and d to 128."""
    n, d = X.shape
    assert n % block_m == 0, (n, block_m)
    grid = (n // block_m,)
    out = pl.pallas_call(
        _rows2_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i: (i, 0)),
            pl.BlockSpec((1, block_m), lambda i: (0, i)),
            pl.BlockSpec((2, d), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((2, block_m), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((2, n), jnp.float32),
        interpret=interpret,
    )(X, sq_norms.reshape(1, n), z2, inv_2s2.reshape(1, 1))
    return out


def _accum_kernel(x_ref, sq_ref, coef_ref, z_ref, qn_ref, inv_ref, out_ref):
    """One (SV tile, query tile) step of the fused decision sum.

    x (bm, d) / sq (bm, 1) / coef (1, bm) stream along the inner grid
    axis; z (bq, d) / qn (1, bq) are pinned per outer step. The kernel
    tile is laid out (bm, bq) — samples on sublanes, queries on lanes —
    so the epilogue contraction coef (1, bm) x k (bm, bq) is a skinny MXU
    matmul straight into the revisited (1, bq) output block.
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...]                                   # (bm, d)
    z = z_ref[...]                                   # (bq, d)
    prods = jax.lax.dot_general(
        x, z, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # (bm, bq)
    d2 = sq_ref[...] - 2.0 * prods + qn_ref[...]     # (bm,1)+(bm,bq)+(1,bq)
    k = jnp.exp(-jnp.maximum(d2, 0.0) * inv_ref[0, 0])
    out_ref[...] += jax.lax.dot_general(
        coef_ref[...], k, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)          # (1, bq)


@functools.partial(jax.jit, static_argnames=("block_m", "block_q",
                                             "interpret"))
def rbf_accumulate(X: jax.Array, sq_norms: jax.Array, coef: jax.Array,
                   Z: jax.Array, inv_2s2: jax.Array, *, block_m: int = 1024,
                   block_q: int = 128, interpret: bool = False) -> jax.Array:
    """out[j] = sum_i coef[i] * K_rbf(Z[j], X[i]) — (B,) decision partials.

    Caller pads M to block_m, B to block_q, d to 128. Padding SV rows must
    carry coef 0 (then their content is irrelevant); padding queries
    produce garbage entries the caller truncates.
    """
    m, d = X.shape
    b = Z.shape[0]
    assert m % block_m == 0 and b % block_q == 0, (m, block_m, b, block_q)
    qn = jnp.sum(Z * Z, axis=-1)
    out = pl.pallas_call(
        _accum_kernel,
        grid=(b // block_q, m // block_m),
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_m, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((1, block_m), lambda i, j: (0, j)),
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block_q), lambda i, j: (0, i)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, b), jnp.float32),
        interpret=interpret,
    )(X, sq_norms.reshape(m, 1), coef.reshape(1, m), Z, qn.reshape(1, b),
      inv_2s2.reshape(1, 1))
    return out.reshape(b)


def _ell_accum_kernel(vals_ref, cols_ref, sq_ref, coef_ref, z_ref, qn_ref,
                      inv_ref, out_ref):
    """ELL twin of ``_accum_kernel``: the (bm, bq) kernel tile comes from
    ``bq`` lane-wise gathers of the dense query rows (the validated
    single-query pattern of sparse_ell.py, unrolled over the static query
    tile — ops.py keeps bq small for exactly this reason)."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    vals = vals_ref[...]                             # (bm, K)
    cols = cols_ref[...]                             # (bm, K) int32
    z = z_ref[...]                                   # (bq, d)
    bq = z.shape[0]
    dots = jnp.stack(
        [jnp.sum(vals * jnp.take(z[q], cols, axis=0), axis=1)
         for q in range(bq)], axis=1)                # (bm, bq)
    d2 = sq_ref[...] - 2.0 * dots + qn_ref[...]      # (bm, bq)
    k = jnp.exp(-jnp.maximum(d2, 0.0) * inv_ref[0, 0])
    out_ref[...] += jax.lax.dot_general(
        coef_ref[...], k, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)          # (1, bq)


@functools.partial(jax.jit, static_argnames=("block_m", "block_q",
                                             "interpret"))
def ell_rbf_accumulate(vals: jax.Array, cols: jax.Array, sq_norms: jax.Array,
                       coef: jax.Array, Z: jax.Array, inv_2s2: jax.Array, *,
                       block_m: int = 512, block_q: int = 8,
                       interpret: bool = False) -> jax.Array:
    """out[j] = sum_i coef[i] * K_rbf(Z[j], x_i) over block-ELL SVs — (B,).

    Same padding contract as :func:`rbf_accumulate` (coef 0 on padding SV
    rows; padding cols are 0, so their gathers stay in bounds).
    """
    m, K = vals.shape
    b, d = Z.shape
    assert m % block_m == 0 and b % block_q == 0, (m, block_m, b, block_q)
    qn = jnp.sum(Z * Z, axis=-1)
    out = pl.pallas_call(
        _ell_accum_kernel,
        grid=(b // block_q, m // block_m),
        in_specs=[
            pl.BlockSpec((block_m, K), lambda i, j: (j, 0)),
            pl.BlockSpec((block_m, K), lambda i, j: (j, 0)),
            pl.BlockSpec((block_m, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((1, block_m), lambda i, j: (0, j)),
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block_q), lambda i, j: (0, i)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, b), jnp.float32),
        interpret=interpret,
    )(vals, cols, sq_norms.reshape(m, 1), coef.reshape(1, m), Z,
      qn.reshape(1, b), inv_2s2.reshape(1, 1))
    return out.reshape(b)
