"""Pallas TPU kernel: fused two-query RBF kernel rows.

out[i, j] = exp(-(||X_i||^2 - 2 <X_i, z_j> + ||z_j||^2) / (2 sigma^2)),
j in {up, low} — the per-iteration hot spot of SMO (DESIGN.md §7).

TPU mapping: the contraction is laid out as z2 (2, d) x X_blk^T (d, bm) ->
(2, bm) so the *lane* dimension is the long sample axis (bm, a multiple of
128) and the MXU sees a well-shaped (pad-to-8, d) x (d, bm) matmul; the
exp runs on the VPU over the same (2, bm) tile. X streams HBM->VMEM once;
norms/γ tiles ride along as (1, bm) row vectors.

Grid: (N / bm,). VMEM per step ~ bm*d*4 bytes for the X tile (+ O(bm)) —
ops.py picks bm so this fits the ~16 MiB VMEM budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rows2_kernel(x_ref, sq_ref, z_ref, inv_ref, out_ref):
    x = x_ref[...]                                   # (bm, d)
    z = z_ref[...]                                   # (2, d)
    # (2, d) x (bm, d)^T -> (2, bm): lane dim = bm
    prods = jax.lax.dot_general(
        z, x, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # (2, bm)
    zn = jnp.sum(z * z, axis=1)                      # (2,)
    d2 = sq_ref[...] - 2.0 * prods + zn[:, None]     # (2, bm) via (1,bm) bcast
    out_ref[...] = jnp.exp(-jnp.maximum(d2, 0.0) * inv_ref[0, 0])


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def rbf_rows2(X: jax.Array, sq_norms: jax.Array, z2: jax.Array,
              inv_2s2: jax.Array, *, block_m: int = 1024,
              interpret: bool = False) -> jax.Array:
    """Returns (2, N) kernel rows. Caller pads N to block_m and d to 128."""
    n, d = X.shape
    assert n % block_m == 0, (n, block_m)
    grid = (n // block_m,)
    out = pl.pallas_call(
        _rows2_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i: (i, 0)),
            pl.BlockSpec((1, block_m), lambda i: (0, i)),
            pl.BlockSpec((2, d), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((2, block_m), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((2, n), jnp.float32),
        interpret=interpret,
    )(X, sq_norms.reshape(1, n), z2, inv_2s2.reshape(1, 1))
    return out
