#!/usr/bin/env bash
# Tier-1 verification: pin test deps and run the full suite on CPU.
# Usage: scripts/verify.sh  (from anywhere inside the repo)
set -euo pipefail
cd "$(dirname "$0")/.."

# Test deps (hypothesis included — tests/test_property.py skips without it,
# but CI should run it). Offline containers keep going with what they have.
python -m pip install --quiet "pytest>=7" "hypothesis>=6.90" "scipy>=1.10" \
    2>/dev/null || echo "WARN: pip install failed (offline?); running with installed deps"

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
