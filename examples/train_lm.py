"""End-to-end LM training driver: ~100M-param model, few hundred steps on
the synthetic token pipeline, with sharding, checkpointing and restart.

    PYTHONPATH=src python examples/train_lm.py --arch xlstm-125m --steps 200
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python examples/train_lm.py --mesh 4,2

Any of the ten ``--arch`` ids works; the default trains the (genuinely
~125M-param) xlstm-125m config at reduced width for CPU tractability.
"""
import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.ckpt import checkpoint as ckpt
from repro.launch.elastic import StragglerWatchdog
from repro.data import TokenPipeline
from repro.launch import train_lib
from repro.models.api import build
from repro.optim import adamw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m",
                    choices=configs.ARCH_IDS)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh", default=None,
                    help="e.g. 4,2 => (data=4, model=2)")
    ap.add_argument("--smoke-width", action="store_true", default=True,
                    help="use the reduced smoke config (CPU container)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = (configs.smoke_config(args.arch) if args.smoke_width
           else configs.full_config(args.arch))
    cfg = dataclasses.replace(cfg, remat="none")
    model = build(cfg)

    from repro.launch.mesh import make_mesh
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split(","))
        mesh = make_mesh(shape, ("data", "model")[: len(shape)])
    else:
        mesh = make_mesh((1,), ("data",))

    tp = TokenPipeline(cfg.vocab_size, batch=args.batch, seq_len=args.seq,
                       seed=0)
    b0 = jax.tree.map(jnp.asarray, tp.batch_at(0))
    if cfg.frontend == "embeds":
        # stub modality frontend: derive frame embeddings from token ids
        emb = np.random.default_rng(0).normal(
            scale=0.02, size=(cfg.vocab_size, cfg.d_model)).astype(np.float32)

        def to_batch(raw):
            return {"embeds": jnp.asarray(emb[raw["tokens"]]),
                    "targets": jnp.asarray(raw["targets"])}
    else:
        def to_batch(raw):
            return jax.tree.map(jnp.asarray, raw)
    b0 = to_batch(tp.batch_at(0))

    p_sh, o_sh, b_sh, (p_shapes, o_shapes) = train_lib.shardings_for(
        cfg, mesh, b0)
    ocfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=20,
                             decay_steps=max(args.steps, 100))
    step_fn = train_lib.make_train_step(cfg, ocfg, mesh)

    start = 0
    from repro.launch import mesh as meshlib
    with meshlib.set_mesh(mesh):
        if args.resume and args.ckpt_dir and ckpt.latest_step(args.ckpt_dir):
            start = ckpt.latest_step(args.ckpt_dir)
            d = os.path.join(args.ckpt_dir, f"step_{start}")
            params = ckpt.restore(d, "params", p_shapes, p_sh)
            opt = ckpt.restore(d, "opt", o_shapes, o_sh)
            print(f"resumed from step {start}")
        else:
            params = jax.jit(lambda k: model.init(cfg, k),
                             out_shardings=p_sh)(jax.random.PRNGKey(0))
            opt = jax.jit(adamw.init, out_shardings=o_sh)(params)

        jstep = jax.jit(step_fn, in_shardings=(p_sh, o_sh, b_sh),
                        out_shardings=(p_sh, o_sh, None),
                        donate_argnums=(0, 1))
        t0 = time.perf_counter()
        pending = None
        wd = StragglerWatchdog(
            threshold=5.0,
            on_straggle=lambda s, dt, med: print(
                f"[watchdog] step {s} took {dt:.2f}s (median {med:.2f}s) — "
                f"straggler path would checkpoint + alert here"))
        for i in range(start, start + args.steps):
            batch = jax.device_put(to_batch(tp.batch_at(i)), b_sh)
            wd.start_step()
            params, opt, metrics = jstep(params, opt, batch)
            jax.block_until_ready(metrics["loss"])
            wd.end_step()
            if i % 10 == 0 or i == start + args.steps - 1:
                dt = time.perf_counter() - t0
                print(f"step {i:4d} loss {float(metrics['loss']):.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.2f} "
                      f"({dt:.1f}s)", flush=True)
            if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
                if pending is not None:
                    pending.join()          # don't stack async saves
                d = os.path.join(args.ckpt_dir, f"step_{i + 1}")
                pending = ckpt.save(d, i + 1,
                                    {"params": params, "opt": opt},
                                    async_=True)
        if pending is not None:
            pending.join()


if __name__ == "__main__":
    main()
