"""Heuristic study (the paper's Table 3 x Figs. 2-8) on one dataset, with
fault-tolerance demo: the run checkpoints every chunk and can be killed +
resumed mid-optimization.

    PYTHONPATH=src python examples/svm_heuristics.py [dataset] [scale]
"""
import sys
import tempfile

from repro.core import SMOSolver, SVMConfig, TABLE3
from repro.data import SPECS, make

ds = sys.argv[1] if len(sys.argv) > 1 else "mushrooms"
scale = float(sys.argv[2]) if len(sys.argv) > 2 else 0.1
spec = SPECS[ds]
X, y, Xt, yt = make(ds, scale=scale, seed=0)
print(f"{ds}: n={X.shape[0]} d={X.shape[1]} | heuristics: {len(TABLE3)}")

rows = []
for name in TABLE3:
    cfg = SVMConfig(C=spec.C, sigma2=spec.sigma2, heuristic=name,
                    chunk_iters=256, min_buffer=128)
    m = SMOSolver(cfg).fit(X, y)
    rows.append((name, m.stats))
    s = m.stats
    print(f"{name:>12} [{TABLE3[name].klass:>12}] iters={s.iterations:5d} "
          f"train={s.train_time:5.2f}s recon={s.recon_time:5.2f}s "
          f"min_active={s.min_active:5d} conv={s.converged}")

base = next(s for n, s in rows if n == "original")
best = min(rows, key=lambda r: r[1].train_time + r[1].recon_time)
bt = best[1].train_time + best[1].recon_time
print(f"\nbest: {best[0]} — "
      f"{(base.train_time + base.recon_time) / max(bt, 1e-9):.2f}x vs Original")

# --- fault tolerance: interrupt after 2 chunks, resume to convergence ----
d = tempfile.mkdtemp()
kw = dict(C=spec.C, sigma2=spec.sigma2, heuristic="multi5pc",
          chunk_iters=128)
SMOSolver(SVMConfig(**kw, max_iters=256, checkpoint_dir=d)).fit(X, y)
resumed = SMOSolver(SVMConfig(**kw, checkpoint_dir=d, resume=True)).fit(X, y)
print(f"resume-from-checkpoint: converged={resumed.stats.converged} "
      f"iters={resumed.stats.iterations}")
