"""Quickstart: train a parallel adaptive-shrinking SVM on a synthetic
dataset and compare heuristics (the paper's core result in ~30 lines).

    PYTHONPATH=src python examples/quickstart.py

Sparse datasets: pass ``format="ell"`` (here or to ``train``) to store
samples in block-ELL sparse form — every row keeps its K nonzeros as
(value, column) pairs, K = max row nnz rounded up to a 128 lane. Memory
crossover rule: ELL buffers beat dense whenever density < d / 2K, i.e.
roughly below 50% density for uniformly sparse data; the paper's text-like
workloads (a9a ~11%, w7a ~4%, rcv1-class <1%) are far inside that regime.
See examples/sparse_svm.py for the memory/time sweep.
"""
import numpy as np

from repro.core import SVMConfig, SMOSolver
from repro.core.parallel import ParallelSMOSolver
from repro.data import SPECS, make

spec = SPECS["a7a"]
X, y, Xt, yt = make("a7a", scale=0.05, seed=0)
print(f"dataset a7a-like: {X.shape[0]} train / {Xt.shape[0]} test, "
      f"d={X.shape[1]}, C={spec.C}, sigma^2={spec.sigma2}")

for heuristic in ("original", "single1000", "multi5pc"):
    cfg = SVMConfig(C=spec.C, sigma2=spec.sigma2, eps=1e-3,
                    heuristic=heuristic, chunk_iters=256)
    # ParallelSMOSolver distributes over every device jax can see
    # (1 here; 8+ with XLA_FLAGS=--xla_force_host_platform_device_count=8)
    model = ParallelSMOSolver(cfg).fit(X, y)
    s = model.stats
    acc = (model.predict(Xt) == yt).mean()
    print(f"{heuristic:>12}: iters={s.iterations:5d} nsv={s.n_sv:4d} "
          f"shrinks={s.shrink_events:3d} recon={s.reconstructions} "
          f"min_active={s.min_active:5d} "
          f"time={s.train_time + s.recon_time:6.2f}s acc={acc:.4f}")

# ---- Serving --------------------------------------------------------------
# ``model.predict`` above already went through the inference plane: a
# cached ``core.serve.ServeEngine`` holding the SVs device-resident and
# scoring pow2-padded query microbatches in one fused dispatch each.
# Deployment knobs: ``compact()`` dedups/prunes the SV set (score-exact in
# fp32), bf16 storage halves resident bytes, ``shards=N``/``use_pallas``
# pick the mesh width and kernel backend, and CSR query batches are
# accepted directly. ``decision_function_host`` is the host-loop oracle
# the engine is tested against. Full latency CLI:
#     python -m repro.launch.serve --svm --dataset a9a --batch 256
compact = model.compact(dtype="bfloat16")        # deployment artifact
engine = compact.serve_engine(min_bucket=32)
scores = engine.decision_function(Xt[:100])
drift = np.abs(scores - model.decision_function_host(Xt[:100])).max()
print(f"serving: {engine.describe()['n_sv']} SVs "
      f"({engine.memory_bytes()} device bytes, bf16), "
      f"bf16-vs-fp32 score drift {drift:.1e}")

# ---- Multi-class and hyperparameter grids ---------------------------------
# K related binary problems — one-vs-rest classes, or one C per grid
# point — share the SAME training set, so MultiProblemDriver trains them
# as ONE batched device program over one resident mirror: joint
# iterations retire problems as they converge, kernel rows are produced
# once and shared through the row cache, and each problem's trajectory
# stays bitwise identical to training it alone (backend="loop" is that
# oracle). CLI: ``python -m repro.launch.svm_train --dataset covtype``
# (OvR) or ``--dataset a7a --grid-c 0.5,2,8`` (C sweep).
from repro.core import MultiProblemDriver, train_ovr

Xc, yc, Xct, yct = make("covtype", scale=0.0008, seed=0)
ovr = train_ovr(Xc, yc, C=spec.C, sigma2=16.0, eps=1e-3,
                heuristic="multi5pc", chunk_iters=128, min_buffer=64,
                row_cache=True, row_cache_slots=256)
st = ovr.stats
print(f"covtype-like OvR: {len(ovr.classes)} classes, "
      f"iters={st.iterations} (joint {st.joint_iters}), "
      f"union SVs={st.n_sv}, "
      f"cache_hit={st.cache_hit_rate:.2f}, "
      f"acc={(ovr.predict(Xct) == yct).mean():.4f}")

# C-grid sweep on the binary problem above: one fit, one model per C
cfg = SVMConfig(C=spec.C, sigma2=spec.sigma2, eps=1e-3,
                heuristic="multi5pc", chunk_iters=128, min_buffer=64)
for C, m in zip([0.5, 2.0, 8.0],
                MultiProblemDriver(cfg).fit_grid(X, y, [0.5, 2.0, 8.0])):
    print(f"  grid C={C:3.1f}: nsv={int((m.alpha > 0).sum())} "
          f"obj={m.dual_objective():.2f}")

# ---- Kill it and resume on a different device count -----------------------
# Checkpoints are crash-atomic (write to a temp dir, publish by rename,
# content checksums — a torn or corrupt save is skipped at resume) and
# MESH-PORTABLE: a step dir holds only the (n,) host masters plus the
# active/membership masks, never a layout, so a fit saved under N devices
# resumes under M by re-dealing the same row set. Resuming on the SAME
# device count replays the killed run bitwise; a different count changes
# shard shapes (a different XLA executable), which keeps iterations and
# objective equal and alpha within ~1 ulp. The injected kill below is a
# stand-in for SIGKILL/preemption at a dispatch boundary; from a shell:
#     python -m repro.launch.svm_train --dataset a9a --devices 4 \
#         --ckpt-dir ckpt/ --chaos kill@12      # killed mid-schedule
#     python -m repro.launch.svm_train --dataset a9a --devices 2 \
#         --ckpt-dir ckpt/ --resume --watchdog-threshold 3.0
# (--watchdog-threshold arms the straggler watchdog: a dispatch slower
# than 3x the running median forces a checkpoint at that boundary and
# halves the fused-dispatch budget, so a preempted host loses nothing.)
import dataclasses
import tempfile

from repro.launch import chaos

ckdir = tempfile.mkdtemp()
cfg = SVMConfig(C=spec.C, sigma2=spec.sigma2, eps=1e-3,
                heuristic="multi5pc", chunk_iters=256,
                checkpoint_dir=ckdir, checkpoint_every=2)
try:
    with chaos.inject(chaos.FaultPlan(kill_at_dispatch=3)):
        ParallelSMOSolver(cfg).fit(X, y)
except chaos.InjectedKill:
    pass                                  # "crashed" at dispatch 3
resumed = ParallelSMOSolver(
    dataclasses.replace(cfg, resume=True)).fit(X, y)
s = resumed.stats
print(f"killed at dispatch 3, resumed from step {s.resumed_from}: "
      f"iters={s.iterations} obj={resumed.dual_objective():.2f}")
