"""Batched serving driver: prefill a batch of prompts, then greedy-decode
with the per-family cache (KV / mLSTM matrix memory / Mamba2 state).

    PYTHONPATH=src python examples/serve_lm.py --arch zamba2-1.2b --tokens 32
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch import train_lib
from repro.models.api import build


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=configs.ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = configs.smoke_config(args.arch)
    model = build(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    max_len = args.prompt_len + args.tokens

    if cfg.frontend == "embeds":
        emb = rng.normal(scale=0.02,
                         size=(cfg.vocab_size, cfg.d_model)).astype(np.float32)
        tok2in = lambda t: {"embeds": jnp.asarray(emb[np.asarray(t)])}
    else:
        tok2in = lambda t: {"tokens": jnp.asarray(t)}

    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)

    # prefill via chunked decode (state families) — one token at a time
    # here for simplicity; transformer prefill_step covers the bulk path.
    cache = model.init_cache(cfg, args.batch, max_len)
    dec = jax.jit(lambda p, c, b: model.decode(p, cfg, c, b))
    t0 = time.perf_counter()
    logits = None
    for t in range(args.prompt_len):
        logits, cache = dec(params, cache, tok2in(prompts[:, t: t + 1]))
    out = [np.asarray(jnp.argmax(logits[:, -1], -1))]
    for _ in range(args.tokens - 1):
        logits, cache = dec(params, cache, tok2in(out[-1][:, None]))
        out.append(np.asarray(jnp.argmax(logits[:, -1], -1)))
    dt = time.perf_counter() - t0
    gen = np.stack(out, 1)
    print(f"{args.arch}: generated {gen.shape} in {dt:.2f}s "
          f"({args.batch * args.tokens / dt:.1f} tok/s incl. compile)")
    print("sample:", gen[0][:16])


if __name__ == "__main__":
    main()
