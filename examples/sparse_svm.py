"""Sparse-format SVM training: the paper's Fig. 1b memory argument, live.

Trains the same synthetic sparse dataset with dense and block-ELL sample
storage (``SVMConfig(format="ell")``) at densities 1%, 5% and 25%, and
reports buffer memory + per-iteration time for each. Rule of thumb: ELL
wins memory whenever density < d / 2K, where K is the per-row nonzero
budget. K is *adaptive*: it starts at the max row nnz (lane-rounded) and
is recomputed from the surviving rows at every shrinking-driven physical
compaction, so the crossover tracks the active set as easy samples are
eliminated (the ``K trajectory`` line below).

CSR ingest: ``fit`` also accepts the paper's CSR layout directly — a
``repro.data.CSRMatrix``, a scipy ``csr_matrix``-like object, or a
``(data, indices, indptr, shape)`` tuple — and streams CSR->ELL buffer
fills without ever materializing a dense X on host (the second section
below). That is the entry point for rcv1/webspam-scale datasets whose
dense form would not fit in memory.

Kernel-row cache: ``SVMConfig(row_cache=True)`` puts a device-resident LRU
cache in front of the kernel-row provider. Cached rows are exact, so the
trajectory is bit-identical to the uncached run — the last section shows
the hit rate and per-iteration win on a repeat-heavy convergence tail.

    PYTHONPATH=src python examples/sparse_svm.py
"""
import jax.numpy as jnp

from repro.core import SMOSolver, SVMConfig
from repro.data import make_repeat_heavy, make_sparse, to_csr

n, d = 1024, 2048
for rho in (0.01, 0.05, 0.25):
    X, y = make_sparse(n, d, rho, seed=0)
    print(f"\ndensity {rho:5.0%}  (n={n}, d={d}, "
          f"nnz/row={int(round(rho * d))})")
    stats = {}
    for fmt in ("dense", "ell"):
        cfg = SVMConfig(C=4.0, sigma2=d / 8.0, heuristic="multi5pc",
                        chunk_iters=256, format=fmt)
        solver = SMOSolver(cfg)
        m = solver.fit(X, y)
        store = solver._store
        buf = store.to_device(store.alloc(m.stats.buffer_sizes[0],
                                          m.stats.buffer_K[0]
                                          if m.stats.buffer_K else None),
                              jnp.asarray)
        us = m.stats.train_time / max(m.stats.iterations, 1) * 1e6
        stats[fmt] = (buf.memory_bytes(), us, m)
        extra = f" K={m.stats.buffer_K}" if fmt == "ell" else ""
        print(f"  {fmt:>5}: buffer={buf.memory_bytes() / 1e6:7.2f} MB  "
              f"{us:7.1f} us/iter  iters={m.stats.iterations:5d}  "
              f"obj={m.dual_objective():.3f}{extra}")
    ratio = stats["ell"][0] / stats["dense"][0]
    print(f"  ELL/dense memory ratio: {ratio:.2f} "
          f"({'ELL wins' if ratio < 1 else 'dense wins'})")

# --- CSR ingest: train straight from the paper's format -------------------
print("\nCSR ingest (no dense host X):")
X, y = make_sparse(1024, 2048, 0.02, seed=1)
csr = to_csr(X)                     # stand-in for a loaded rcv1-style file
del X                               # the solver only ever sees CSR
solver = SMOSolver(SVMConfig(C=4.0, sigma2=2048 / 8.0, heuristic="multi5pc",
                             chunk_iters=256, format="ell"))
m = solver.fit(csr, y)
print(f"  store={type(solver._store).__name__}  "
      f"csr={solver._store.memory_bytes() / 1e6:.2f} MB on host  "
      f"iters={m.stats.iterations}  obj={m.dual_objective():.3f}  "
      f"K trajectory={m.stats.buffer_K}")

# --- kernel-row LRU cache: exact, and free FLOPs on the hot tail ----------
# Repeat-heavy workload: a low-tolerance convergence tail that bounces
# inside a hot working set smaller than the slot count. (Hit rate is
# workload-dependent — a working set wider than the slot count cycles
# through the LRU and caches nothing; see benchmarks/sparse_bench.py.)
print("\nkernel-row cache (row_cache=True; bit-identical trajectories):")
X, y = make_repeat_heavy(2048, 768, 0.25, seed=1)
for rc in (False, True):
    cfg = SVMConfig(C=8.0, sigma2=768 / 8.0, eps=1e-5, heuristic="original",
                    chunk_iters=512, format="ell", row_cache=rc,
                    row_cache_slots=1024)
    m = SMOSolver(cfg).fit(X, y)
    us = m.stats.train_time / max(m.stats.iterations, 1) * 1e6
    extra = (f"  hit_rate={m.stats.cache_hit_rate:.2f}" if rc else "")
    print(f"  cache={'on ' if rc else 'off'}: {us:7.1f} us/iter  "
          f"iters={m.stats.iterations}  flops={m.stats.flops_est:.3g}"
          f"{extra}")
