"""Sparse-format SVM training: the paper's Fig. 1b memory argument, live.

Trains the same synthetic sparse dataset with dense and block-ELL sample
storage (``SVMConfig(format="ell")``) at densities 1%, 5% and 25%, and
reports buffer memory + per-iteration time for each. Rule of thumb: ELL
wins memory whenever density < d / 2K, where K is the per-row nonzero
budget (max row nnz rounded up to a 128 lane).

    PYTHONPATH=src python examples/sparse_svm.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import SMOSolver, SVMConfig
from repro.data import make_sparse

n, d = 1024, 2048
for rho in (0.01, 0.05, 0.25):
    X, y = make_sparse(n, d, rho, seed=0)
    print(f"\ndensity {rho:5.0%}  (n={n}, d={d}, "
          f"nnz/row={int(round(rho * d))})")
    stats = {}
    for fmt in ("dense", "ell"):
        cfg = SVMConfig(C=4.0, sigma2=d / 8.0, heuristic="multi5pc",
                        chunk_iters=256, format=fmt)
        solver = SMOSolver(cfg)
        m = solver.fit(X, y)
        store = solver._store
        buf = store.to_device(store.alloc(m.stats.buffer_sizes[0]),
                              jnp.asarray)
        us = m.stats.train_time / max(m.stats.iterations, 1) * 1e6
        stats[fmt] = (buf.memory_bytes(), us, m)
        extra = f" K={store.K}" if fmt == "ell" else ""
        print(f"  {fmt:>5}: buffer={buf.memory_bytes() / 1e6:7.2f} MB  "
              f"{us:7.1f} us/iter  iters={m.stats.iterations:5d}  "
              f"obj={m.dual_objective():.3f}{extra}")
    ratio = stats["ell"][0] / stats["dense"][0]
    print(f"  ELL/dense memory ratio: {ratio:.2f} "
          f"({'ELL wins' if ratio < 1 else 'dense wins'})")
