"""Benchmark harness — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

  figs 2-8   heuristic sweeps on the seven dataset stand-ins
  fig 9      best-heuristic summary (speedup vs Original + accuracy)
  fig 1b     CSR/ELL space conservation
  scaling    process-count scaling (subprocess per device count)
  roofline   LM arch x shape terms from results/dryrun_all.json (if present)

Full sweep: ``python -m benchmarks.run``; quick subset: ``--quick``.

``--bench-summary BENCH_summary.json`` skips the sweeps and instead merges
every ``BENCH_*.json`` artifact in the working directory (the per-sweep
files ``sparse_bench.py`` writes and CI uploads individually) into ONE
summary artifact: per bench, the record count plus min/max of the headline
metrics, so a single download answers "did the batched multi win hold, did
the cache hit, what's the ELL ratio" without opening seven files.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

# metric keys worth surfacing in the merged artifact; everything else in
# the per-bench records stays in the per-bench files
_HEADLINE_KEYS = ("speedup", "speedup_vs_sequential", "hit_rate",
                  "us_per_iter", "us_per_iter_problem", "us_per_row",
                  "us_per_point", "bytes_ratio", "iterations")


def summarize_benches(out_path: str, pattern: str = "BENCH_*.json") -> dict:
    """Merge every per-sweep ``BENCH_*.json`` into one summary dict and
    write it to ``out_path``. Returns the summary."""
    benches = {}
    for path in sorted(glob.glob(pattern)):
        if os.path.abspath(path) == os.path.abspath(out_path):
            continue
        try:
            with open(path) as f:
                blob = json.load(f)
        except (OSError, ValueError) as e:
            benches[os.path.basename(path)] = {"error": str(e)}
            continue
        records = [r for r in blob.get("records", [])
                   if isinstance(r, dict)]
        head = {}
        for key in _HEADLINE_KEYS:
            vals = [r[key] for r in records
                    if isinstance(r.get(key), (int, float))]
            if vals:
                head[key] = {"min": min(vals), "max": max(vals)}
        benches[blob.get("bench", os.path.basename(path))] = {
            "file": os.path.basename(path),
            "n_records": len(records),
            "headline": head,
        }
    summary = {"bench": "summary", "benches": benches}
    with open(out_path, "w") as f:
        json.dump(summary, f, indent=1)
    return summary


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="2 datasets, 4 heuristics, no scaling")
    ap.add_argument("--no-scaling", action="store_true")
    ap.add_argument("--bench-summary", default=None, metavar="OUT",
                    help="merge BENCH_*.json artifacts in the working "
                         "directory into OUT and exit (no sweeps)")
    args = ap.parse_args()

    if args.bench_summary:
        summary = summarize_benches(args.bench_summary)
        for name, info in summary["benches"].items():
            print(f"{name}: {info.get('n_records', '?')} records "
                  f"from {info.get('file', '?')}", flush=True)
        print(f"wrote {args.bench_summary}", flush=True)
        return

    from benchmarks import svm_figs
    print("name,us_per_call,derived")

    datasets = (["a7a", "usps"] if args.quick else
                ["a7a", "a9a", "usps", "mushrooms", "w7a", "ijcnn", "mnist"])
    heuristics = (["original", "single500", "multi500", "multi5pc"]
                  if args.quick else svm_figs.DEFAULT_HEURISTICS)

    results = {}
    for ds in datasets:
        rows = svm_figs.bench_dataset(ds, heuristics=heuristics)
        results[ds] = rows
        for r in rows:
            print(r.csv(), flush=True)

    for line in svm_figs.fig9_summary(results):
        print(line, flush=True)
    for line in svm_figs.fig1b_space():
        print(line, flush=True)

    # sparse data plane: dense vs fixed-K vs adaptive-K ELL (Fig. 1b)
    from benchmarks import sparse_bench
    kw = dict(n=512, d=1024) if args.quick else {}
    for line in sparse_bench.csv_lines(sparse_bench.bench_sparse(**kw)):
        print(line, flush=True)

    if not (args.quick or args.no_scaling):
        from benchmarks import scaling
        for line in scaling.bench_scaling():
            print(line, flush=True)

    # roofline table (from the dry-run sweep, if it has been produced)
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "results", "dryrun_all.json")
    if os.path.exists(path):
        with open(path) as f:
            cells = json.load(f)
        for c in cells:
            if c.get("status") != "ok" or "t_compute_s" not in c:
                continue
            dom_t = max(c["t_compute_s"], c.get("t_memory_est_s", 0.0),
                        c["t_collective_s"])
            frac = c["t_compute_s"] / dom_t if dom_t else 0.0
            print(f"roofline/{c['arch']}__{c['shape']}__{c['mesh']},"
                  f"{dom_t * 1e6:.0f},"
                  f"dominant={c['dominant']};roofline_frac={frac:.3f};"
                  f"useful={c['useful_ratio']:.2f}", flush=True)


if __name__ == "__main__":
    main()
