"""Benchmark harness — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

  figs 2-8   heuristic sweeps on the seven dataset stand-ins
  fig 9      best-heuristic summary (speedup vs Original + accuracy)
  fig 1b     CSR/ELL space conservation
  scaling    process-count scaling (subprocess per device count)
  roofline   LM arch x shape terms from results/dryrun_all.json (if present)

Full sweep: ``python -m benchmarks.run``; quick subset: ``--quick``.
"""
from __future__ import annotations

import argparse
import json
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="2 datasets, 4 heuristics, no scaling")
    ap.add_argument("--no-scaling", action="store_true")
    args = ap.parse_args()

    from benchmarks import svm_figs
    print("name,us_per_call,derived")

    datasets = (["a7a", "usps"] if args.quick else
                ["a7a", "a9a", "usps", "mushrooms", "w7a", "ijcnn", "mnist"])
    heuristics = (["original", "single500", "multi500", "multi5pc"]
                  if args.quick else svm_figs.DEFAULT_HEURISTICS)

    results = {}
    for ds in datasets:
        rows = svm_figs.bench_dataset(ds, heuristics=heuristics)
        results[ds] = rows
        for r in rows:
            print(r.csv(), flush=True)

    for line in svm_figs.fig9_summary(results):
        print(line, flush=True)
    for line in svm_figs.fig1b_space():
        print(line, flush=True)

    # sparse data plane: dense vs fixed-K vs adaptive-K ELL (Fig. 1b)
    from benchmarks import sparse_bench
    kw = dict(n=512, d=1024) if args.quick else {}
    for line in sparse_bench.csv_lines(sparse_bench.bench_sparse(**kw)):
        print(line, flush=True)

    if not (args.quick or args.no_scaling):
        from benchmarks import scaling
        for line in scaling.bench_scaling():
            print(line, flush=True)

    # roofline table (from the dry-run sweep, if it has been produced)
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "results", "dryrun_all.json")
    if os.path.exists(path):
        with open(path) as f:
            cells = json.load(f)
        for c in cells:
            if c.get("status") != "ok" or "t_compute_s" not in c:
                continue
            dom_t = max(c["t_compute_s"], c.get("t_memory_est_s", 0.0),
                        c["t_collective_s"])
            frac = c["t_compute_s"] / dom_t if dom_t else 0.0
            print(f"roofline/{c['arch']}__{c['shape']}__{c['mesh']},"
                  f"{dom_t * 1e6:.0f},"
                  f"dominant={c['dominant']};roofline_frac={frac:.3f};"
                  f"useful={c['useful_ratio']:.2f}", flush=True)


if __name__ == "__main__":
    main()
