"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the sweep JSONs.

    PYTHONPATH=src:. python benchmarks/make_experiments_tables.py \
        results/dryrun_all.json [results/dryrun_baseline.json]
"""
import json
import sys


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def table(cells, baseline=None):
    base = {}
    if baseline:
        for c in baseline:
            if c.get("status") == "ok" and "t_collective_s" in c:
                base[(c["arch"], c["shape"], c["mesh"])] = c
    out = []
    out.append("| arch | shape | mesh | HBM GiB/dev | t_compute | t_memory"
               "(hlo) | t_memory(est) | t_collective | dominant | useful |"
               " roofline frac | vs baseline coll |")
    out.append("|---|---|---|---:|---:|---:|---:|---:|---|---:|---:|---:|")
    for c in cells:
        if c.get("status") == "skip":
            out.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} | — | — |"
                       f" — | — | — | SKIP | — | — | {c['reason'][:40]}… |")
            continue
        if c.get("status") != "ok":
            out.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} | — | — |"
                       f" — | — | — | ERROR | — | — | — |")
            continue
        mem = (c["memory"]["argument_size_in_bytes"]
               + c["memory"]["temp_size_in_bytes"]) / 2**30
        if "t_compute_s" not in c:          # multi-pod: compile proof only
            out.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} |"
                       f" {mem:.2f} | — | — | — | — | compiled ✓ | — | — |"
                       f" — |")
            continue
        tc, tm, tme, tl = (c["t_compute_s"], c["t_memory_s"],
                           c.get("t_memory_est_s", 0.0), c["t_collective_s"])
        dom = max(tc, tme, tl)
        frac = tc / dom if dom else 0.0
        b = base.get((c["arch"], c["shape"], c["mesh"]))
        delta = (f"{b['t_collective_s'] / tl:.2f}x"
                 if b and tl else "—")
        out.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | {mem:.2f} |"
            f" {tc*1e3:.1f}ms | {tm*1e3:.1f}ms | {tme*1e3:.1f}ms |"
            f" {tl*1e3:.1f}ms | {c['dominant']} |"
            f" {c['useful_ratio']:.2f} | {frac:.2f} | {delta} |")
    return "\n".join(out)


def main():
    cells = json.load(open(sys.argv[1]))
    baseline = json.load(open(sys.argv[2])) if len(sys.argv) > 2 else None
    single = [c for c in cells if c["mesh"] == "16x16"]
    multi = [c for c in cells if c["mesh"] == "2x16x16"]
    print("### Single-pod (16x16 = 256 chips) — roofline table\n")
    print(table(single, baseline))
    print("\n### Multi-pod (2x16x16 = 512 chips) — compile/memory proof\n")
    print(table(multi, baseline))
    ok = sum(c["status"] == "ok" for c in cells)
    sk = sum(c["status"] == "skip" for c in cells)
    er = sum(c["status"] == "error" for c in cells)
    print(f"\nTotal: {ok} compiled ok, {sk} documented skips, {er} errors.")


if __name__ == "__main__":
    main()
