"""Sparse-format + kernel-row-cache + compaction benchmarks.

Sparse sweep: ELL vs dense training storage (paper Fig. 1b).

For each density in the sweep, builds a ``make_sparse`` dataset and trains
three configurations of the same problem:

  * ``dense``        — dense sample buffers,
  * ``ell-fixed``    — block-ELL with the PR-1 behavior: K pinned to the
                       store-wide ingest budget (``ell_adaptive=False``),
  * ``ell-adaptive`` — block-ELL with per-buffer K recompaction (the lane
                       budget tracks the surviving rows at every physical
                       compaction).

On top of the percent-scale density sweep, ``SPECS`` adds rcv1/webspam-
class synthetic workloads — text-feature matrices with density well under
1% — at CI-scale n. These are the regime the CSR ingest + adaptive-K data
plane targets (the dense run exists only as the memory/latency baseline),
and their records ride in the same ``BENCH_sparse.json`` artifact.

Reported per configuration: buffer memory of the initial training buffer,
per-SMO-iteration wall time, iteration count, dual objective, and for ELL
runs the K trajectory across buffer builds. CSV rows (stdout) keep the
historical ``sparse/<density>/<fmt>,us_per_iter,derived`` shape; ``--out``
additionally writes the full sweep as a JSON artifact (``BENCH_sparse.json``
in CI) so the perf trajectory accumulates across PRs.

Cache sweep (``--cache-out`` -> ``BENCH_cache.json``): trains a
repeat-heavy workload — a long low-tolerance convergence tail bouncing
inside a hot working set, the access pattern the device-resident LRU
kernel-row cache exists for — with the cache off and on, for both storage
formats. Reports hit rate, us/iter, and the cache-aware FLOP estimate, and
asserts the exactness contract (identical iteration counts) en passant.

Compaction sweep (``--compact-out`` -> ``BENCH_compact.json``): trains a
shrink-heavy workload under both physical-compaction backends —
``'host'`` (store rebuild through numpy + host cache remap) and
``'device'`` (one jitted gather; zero host row/cache traffic) — across
buffer sizes, with the row cache on so the (slots, M) value-table remap is
part of what is measured. Reports per-compaction latency and the
device/host speedup, and asserts the backends' bitwise trajectory parity
(identical iteration counts) en passant.

Reconstruction sweep (``--recon-out`` -> ``BENCH_reconstruct.json``):
trains a reconstruction-heavy workload under both Alg. 6 backends —
``mirror='host'`` (every SV / stale-row block built in host numpy and
shipped per block) and ``mirror='device'`` (one jitted scan over the
device-resident full-set mirror) — across dense/ELL and problem sizes.
``recon_block`` is set well below the problem size so the block GRID is
real (several SV x query cells): that is the regime host streaming pays
per-block python dispatch, numpy densify, and host->device transfers in,
i.e. the large-n regime scaled down to CI budgets (at CI sizes a single
8192-row block would make the sweep measure one GEMM, identical in both
backends). Reports per-reconstruction latency and the device/host
speedup, and asserts bitwise backend parity (identical iteration counts)
en passant. On CPU the "host link" is memcpy, so the win here is pure
orchestration — expect substantially bigger ratios across PCIe/ICI.

Fused-epoch sweep (``--epoch-out`` -> ``BENCH_epoch.json``): trains a
shrink-heavy workload at ``fuse_iters`` in {1, 4, 16, 64} across
dense/ELL specs. Every k shares ONE executable (the schedule scalars are
traced) and the k=1 run is the bit-exact oracle — parity (identical
iteration counts) is asserted en passant — so the only thing the sweep
varies is how many per-dispatch host round-trips (launch + one
``EpochSummary`` sync each) the same iteration trajectory is amortized
over. us/iter should fall monotonically to the amortization knee, where
dispatch overhead stops being a measurable share of an iteration; on CPU
the sync is cheap, so across PCIe/ICI the knee sits at larger k.

Multi-problem sweep (``--multi-out`` -> ``BENCH_multi.json``): batched
K-problem training (``core.multi.MultiProblemDriver``, K SMO subproblems
sharing one device mirror with amortized kernel-row production) against
K sequential fits, sweeping K in {2, 4, 8, 16} x dense/ELL x row-cache
on/off on a repeat-heavy C-grid workload. Reports aggregate us per
iteration*problem and the shared-cache hit rate; asserts en passant the
bitwise per-problem parity against the sequential oracle, the batched
throughput win for K >= 4, and that cross-problem row reuse lifts the
cache hit rate strictly above the single-problem baseline.

Serving sweep (``--serve-out`` -> ``BENCH_serve.json``): the inference
plane (``core/serve.ServeEngine``) against the seed-era host block loop
(``decision_function_host``) across batch size x SV count x storage format
x SV dtype. Reports p50/p99 latency, QPS and us/query for both paths, the
engine's roofline terms for the hot bucket executable
(``launch/roofline.py`` pricing), and asserts en passant: engine-vs-host
score parity, engine wins on us/query at batch >= 64, compacted-vs-full
score parity, and (in a 4-device subprocess) sharded-vs-single-device
parity.

Elastic sweep (``--elastic-out`` -> ``BENCH_elastic.json``): the
checkpoint plane — atomic save and checksum-verified restore latency of
the epoch driver's step-dir payload vs n (each timed save overwrites the
last, so the rename-aside publish path is what is measured), plus (in a
4-device subprocess) ``elastic.rescale`` latency restoring one saved
step onto 1/2/4-device meshes, i.e. the checkpoint -> new-topology path
a rescaled resume pays before its first dispatch.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import textwrap
import time

import numpy as np

from repro.core import ServeEngine, SMOSolver, SVMConfig
from repro.data import make_repeat_heavy, make_sparse

DENSITIES = (0.01, 0.05, 0.25)

# rcv1/webspam-scale synthetic specs: <1% density text-like workloads
# (rcv1 measures ~0.16% over d=47k; webspam unigrams ~2% over d=254 but the
# trigram form is ~3e-5 over d=16M — both collapse to "K lanes << d").
# Scaled to CI-budget n/d, densities kept under 1%.
SPECS = (
    {"name": "rcv1-like", "n": 1536, "d": 6144, "density": 0.004,
     "quick": {"n": 640, "d": 3072}},
    {"name": "webspam-like", "n": 2048, "d": 4096, "density": 0.008,
     "quick": {"n": 768, "d": 2048}},
    # Multi-class OvR workloads (covtype/news20 stand-ins from
    # data.synthetic.SPECS): K one-vs-rest problems trained as ONE batched
    # fit through core.multi.MultiProblemDriver — the spec's C/sigma2 ride
    # in from the DatasetSpec; ``scale`` picks the CI-budget N.
    {"name": "covtype-like", "spec": "covtype", "n_classes": 7,
     "scale": 0.0015, "quick": {"scale": 0.0008}},
    {"name": "news20-like", "spec": "news20", "n_classes": 20,
     "scale": 0.008, "quick": {"scale": 0.005}},
)

CONFIGS = (
    ("dense", dict(format="dense")),
    ("ell-fixed", dict(format="ell", ell_adaptive=False)),
    ("ell-adaptive", dict(format="ell", ell_adaptive=True)),
)


def _bench_dataset(X, y, n: int, d: int, heuristic: str, eps: float,
                   meta: dict) -> list[dict]:
    """Train the three storage CONFIGS on one dataset; returns records with
    ``meta`` merged in, ELL objectives asserted against the dense run."""
    import jax.numpy as jnp
    records = []
    by_name = {}
    for name, overrides in CONFIGS:
        cfg = SVMConfig(C=4.0, sigma2=float(d) / 8.0, eps=eps,
                        heuristic=heuristic, chunk_iters=256,
                        **overrides)
        solver = SMOSolver(cfg)
        m = solver.fit(X, y)
        store = solver._store
        buf = store.alloc(m.stats.buffer_sizes[0],
                          m.stats.buffer_K[0] if m.stats.buffer_K
                          else None)
        mem = store.to_device(buf, jnp.asarray).memory_bytes()
        rec = {
            **meta, "fmt": name, "n": n, "d": d,
            "us_per_iter": (m.stats.train_time /
                            max(m.stats.iterations, 1)) * 1e6,
            "iterations": m.stats.iterations,
            "mem_bytes": mem,
            "obj": m.dual_objective(),
            "compactions": m.stats.compactions,
            "buffer_K": list(m.stats.buffer_K),
        }
        by_name[name] = rec
        records.append(rec)
    ref = by_name["dense"]["obj"]
    for name in ("ell-fixed", "ell-adaptive"):
        rel = abs(by_name[name]["obj"] - ref) / max(abs(ref), 1e-9)
        assert rel < 1e-2, \
            f"{name}/dense objective diverged at {meta}: {rel}"
        by_name[name]["mem_ratio"] = \
            by_name[name]["mem_bytes"] / by_name["dense"]["mem_bytes"]
    return records


def _bench_multiclass(spec: dict, eps: float, seed: int) -> list[dict]:
    """One-vs-rest training of a multi-class spec through the batched
    multi-problem driver, dense vs ELL storage; the summed per-class dual
    objective is asserted ELL-vs-dense like the binary sweep."""
    from repro.core.multi import MultiProblemDriver
    from repro.data import SPECS as DATA_SPECS, make
    ds = DATA_SPECS[spec["spec"]]
    X, y, _, _ = make(ds, scale=spec["scale"], seed=seed)
    n, d = X.shape
    records, by_fmt = [], {}
    for fmt in ("dense", "ell"):
        cfg = SVMConfig(C=ds.C, sigma2=ds.sigma2, eps=eps,
                        heuristic="multi5pc", chunk_iters=128,
                        min_buffer=64, format=fmt)
        mdl = MultiProblemDriver(cfg).fit_ovr(X, y)
        st = mdl.stats
        total_it = sum(r["iterations"] for r in st.per_problem)
        rec = {
            "spec": spec["name"], "fmt": fmt, "n": n, "d": d,
            "n_classes": int(spec["n_classes"]),
            "iterations": total_it,
            "us_per_iter_problem": (st.train_time * 1e6
                                    / max(total_it, 1)),
            "obj": float(sum(m.dual_objective() for m in mdl.models)),
            "n_sv_union": (int(mdl._union.sv_coef.shape[0])
                           if mdl._union is not None else 0),
        }
        by_fmt[fmt] = rec
        records.append(rec)
    rel = (abs(by_fmt["ell"]["obj"] - by_fmt["dense"]["obj"])
           / max(abs(by_fmt["dense"]["obj"]), 1e-9))
    assert rel < 1e-2, f"ell/dense OvR objective diverged at {spec}: {rel}"
    return records


def bench_sparse(n: int = 1024, d: int = 2048, densities=DENSITIES,
                 heuristic: str = "single1000", eps: float = 1e-3,
                 seed: int = 0, quick: bool = False) -> list[dict]:
    records = []
    for rho in densities:
        X, y = make_sparse(n, d, rho, seed=seed)
        records += _bench_dataset(X, y, n, d, heuristic, eps,
                                  {"density": rho})
    for spec in SPECS:
        dims = {**spec, **spec["quick"]} if quick else spec
        if spec.get("n_classes", 2) > 2:
            records += _bench_multiclass(dims, eps, seed)
            continue
        ns, ds = dims["n"], dims["d"]
        X, y = make_sparse(ns, ds, spec["density"], seed=seed)
        records += _bench_dataset(
            X, y, ns, ds, heuristic, eps,
            {"density": spec["density"], "spec": spec["name"]})
    return records


def bench_cache(n: int = 3072, d: int = 768, density: float = 0.25,
                eps: float = 1e-5, slots: int = 2048,
                seed: int = 1) -> list[dict]:
    """Row-cache on/off sweep on a repeat-heavy workload (see module doc).

    Sized so the row pass dominates the cache machinery even in --quick
    mode: shrinking the problem much below (2048, 768) makes the win
    vanish into fixed per-iteration overhead and the artifact stops
    demonstrating anything.
    """
    X, y = make_repeat_heavy(n, d, density, seed=seed)
    records = []
    for fmt in ("dense", "ell"):
        by_cache = {}
        for rc in (False, True):
            cfg = SVMConfig(C=8.0, sigma2=float(d) / 8.0, eps=eps,
                            heuristic="original", chunk_iters=512,
                            format=fmt, row_cache=rc, row_cache_slots=slots)
            m = SMOSolver(cfg).fit(X, y)
            rec = {
                "fmt": fmt, "cache": rc, "n": n, "d": d,
                "density": density, "eps": eps, "slots": slots,
                "us_per_iter": (m.stats.train_time /
                                max(m.stats.iterations, 1)) * 1e6,
                "iterations": m.stats.iterations,
                "hit_rate": m.stats.cache_hit_rate,
                "cache_hits": m.stats.cache_hits,
                "cache_misses": m.stats.cache_misses,
                "flops_est": m.stats.flops_est,
                "obj": m.dual_objective(),
            }
            by_cache[rc] = rec
            records.append(rec)
        # the cache is exact: identical trajectories by construction
        assert by_cache[True]["iterations"] == by_cache[False]["iterations"], \
            (fmt, by_cache)
        assert by_cache[True]["hit_rate"] > 0.0, (fmt, by_cache)
        by_cache[True]["speedup"] = (by_cache[False]["us_per_iter"] /
                                     by_cache[True]["us_per_iter"])
    return records


def bench_compact(sizes=(2048, 8192), d: int = 384, density: float = 0.05,
                  eps: float = 1e-3, seed: int = 3) -> list[dict]:
    """Host vs device physical-compaction latency across buffer sizes.

    A wide-margin ``make_sparse`` problem under the Multi policy shrinks
    aggressively, so each fit goes through several physical compactions;
    the row cache is on so the (slots, M) value-table remap — the
    host-side bottleneck ROADMAP calls out — is part of the measured path.
    Each configuration is fit twice and the second run reported, so the
    device numbers are warm-jit (the compaction step executable is cached
    across fits at equal shapes).
    """
    records = []
    for n in sizes:
        X, y = make_sparse(n, d, density, seed=seed, noise=0.05,
                           label_noise=0.0, margin=0.5)
        for fmt in ("dense", "ell"):
            by = {}
            for backend in ("host", "device"):
                cfg = SVMConfig(C=2.0, sigma2=float(d) / 8.0, eps=eps,
                                heuristic="multi5pc", chunk_iters=64,
                                min_buffer=64, format=fmt,
                                row_cache=True, row_cache_slots=256,
                                compact_backend=backend)
                m = None
                for _ in range(2):            # second run = warm jit
                    m = SMOSolver(cfg).fit(X, y)
                rec = {
                    "n": n, "d": d, "fmt": fmt, "backend": backend,
                    "compactions": m.stats.compactions,
                    "iterations": m.stats.iterations,
                    "buffer_sizes": list(m.stats.buffer_sizes),
                    "us_per_compact": (m.stats.compact_time * 1e6
                                       / max(m.stats.compactions, 1)),
                }
                by[backend] = rec
                records.append(rec)
            # the backends are bit-identical by contract
            assert by["host"]["iterations"] == by["device"]["iterations"], \
                (n, fmt, by)
            assert by["device"]["compactions"] > 0, (n, fmt, by)
            by["device"]["speedup"] = (by["host"]["us_per_compact"]
                                       / by["device"]["us_per_compact"])
    return records


def bench_recon(sizes=(1536, 3072), d: int = 384, density: float = 0.05,
                eps: float = 1e-3, recon_block: int = 512,
                seed: int = 3, fits: int = 3) -> list[dict]:
    """Host-streaming vs device-mirror Alg. 6 latency (see module doc).

    Each configuration is fit ``fits`` times and the minimum recon_time of
    the warm fits (all reconstruction executables cached) is reported —
    reconstruction runs only 2-3 times per fit, so a single fit's timing
    is noise-bound.
    """
    records = []
    for n in sizes:
        X, y = make_sparse(n, d, density, seed=seed, noise=0.05,
                           label_noise=0.0, margin=0.5)
        for fmt in ("dense", "ell"):
            by = {}
            for mode in ("host", "device"):
                cfg = SVMConfig(C=2.0, sigma2=float(d) / 8.0, eps=eps,
                                heuristic="multi5pc", chunk_iters=64,
                                min_buffer=64, format=fmt, mirror=mode,
                                recon_block=recon_block)
                m, best = None, float("inf")
                for k in range(fits):
                    m = SMOSolver(cfg).fit(X, y)
                    if k > 0:                 # warm fits only
                        best = min(best, m.stats.recon_time)
                rec = {
                    "n": n, "d": d, "fmt": fmt, "mirror": mode,
                    "recon_block": recon_block,
                    "reconstructions": m.stats.reconstructions,
                    "iterations": m.stats.iterations,
                    "us_per_recon": (best * 1e6
                                     / max(m.stats.reconstructions, 1)),
                }
                by[mode] = rec
                records.append(rec)
            # the backends are bit-identical by contract
            assert by["host"]["iterations"] == by["device"]["iterations"], \
                (n, fmt, by)
            assert by["device"]["reconstructions"] >= 1, (n, fmt, by)
            by["device"]["speedup"] = (by["host"]["us_per_recon"]
                                       / by["device"]["us_per_recon"])
    return records


FUSE_SWEEP = (1, 4, 16, 64)


def bench_epoch(sizes=(1536, 3072), d: int = 384, density: float = 0.05,
                eps: float = 1e-3, seed: int = 3,
                sweep=FUSE_SWEEP) -> list[dict]:
    """us/iter vs ``fuse_iters`` on shrink-heavy dense/ELL specs (see
    module doc). Each configuration is fit twice and the second run
    reported, so the numbers are warm-jit — which also exercises the
    one-executable-for-all-k property: k > 1 fits recompile nothing.
    """
    records = []
    for n in sizes:
        X, y = make_sparse(n, d, density, seed=seed, noise=0.05,
                           label_noise=0.0, margin=0.5)
        for fmt in ("dense", "ell"):
            oracle = None
            for k in sweep:
                cfg = SVMConfig(C=2.0, sigma2=float(d) / 8.0, eps=eps,
                                heuristic="multi5pc", chunk_iters=64,
                                min_buffer=64, format=fmt,
                                row_cache=True, row_cache_slots=256,
                                fuse_iters=k)
                m = None
                for _ in range(2):            # second run = warm jit
                    m = SMOSolver(cfg).fit(X, y)
                rec = {
                    "n": n, "d": d, "fmt": fmt, "fuse_iters": k,
                    "iterations": m.stats.iterations,
                    "dispatches": m.stats.dispatches,
                    "us_per_iter": (m.stats.train_time * 1e6
                                    / max(m.stats.iterations, 1)),
                    "us_per_dispatch": (m.stats.train_time * 1e6
                                        / max(m.stats.dispatches, 1)),
                    "compactions": m.stats.compactions,
                    "shrink_events": m.stats.shrink_events,
                }
                records.append(rec)
                if k == sweep[0]:
                    oracle = rec
                else:
                    # any k is bit-identical to the k=1 oracle by contract
                    assert rec["iterations"] == oracle["iterations"], \
                        (n, fmt, k, rec, oracle)
                    assert rec["shrink_events"] == oracle["shrink_events"], \
                        (n, fmt, k, rec, oracle)
                    assert rec["dispatches"] < oracle["dispatches"], \
                        (n, fmt, k, rec, oracle)
                    rec["speedup"] = (oracle["us_per_iter"]
                                      / rec["us_per_iter"])
    return records


MULTI_KS = (2, 4, 8, 16)


def bench_multi(n: int = 768, d: int = 256, density: float = 0.2,
                eps: float = 1e-3, seed: int = 5, Ks=MULTI_KS,
                slots: int = 768) -> list[dict]:
    """Batched K-problem training vs K sequential fits (see module doc).

    A repeat-heavy workload swept as a C grid: problem k trains at
    ``CS_ALL[k]`` regardless of which batch it rides in, so ONE
    sequential 16-fit sweep per format is both the timing baseline and
    the per-problem parity oracle for every K (a problem's trajectory
    depends only on (X, y, C), never on its batch-mates — that is the
    exactness contract the assert pins, bitwise on alpha).

    Timing: the sequential baseline amortizes its one-time compile over
    the 16-fit sweep (exactly what a real sequential grid run pays); the
    batched side is fit twice per (K, cache) and the warm second fit
    reported, since each (K, format, cache) shape is a fresh executable.
    Reported metric is aggregate us per iteration*problem — total wall
    time over total per-problem productive iterations.

    Cache-on runs must show cross-problem row reuse: the shared-cache
    hit rate is asserted strictly above the single-problem (K=1)
    baseline for K >= 4, and the batched-vs-sequential throughput win is
    asserted for K >= 4.
    """
    from repro.core.multi import MultiProblemDriver
    X, y = make_repeat_heavy(n, d, density, seed=seed)
    CS_ALL = np.geomspace(0.5, 8.0, max(Ks))
    records = []

    def mkcfg(fmt, rc, C=1.0):
        return SVMConfig(C=C, sigma2=float(d) / 8.0, eps=eps,
                         heuristic="multi5pc", chunk_iters=128,
                         fuse_iters=4, min_buffer=64, format=fmt,
                         row_cache=rc, row_cache_slots=slots)

    for fmt in ("dense", "ell"):
        # single-problem cache baseline: the hit rate K lanes must beat
        m1 = SMOSolver(mkcfg(fmt, True)).fit(X, y)
        base_hit = m1.stats.cache_hit_rate
        records.append({"fmt": fmt, "K": 1, "cache": True,
                        "kind": "single_baseline", "hit_rate": base_hit,
                        "iterations": m1.stats.iterations})
        # ONE sequential sweep over all 16 C values: oracle + baseline
        t0 = time.perf_counter()
        ml = MultiProblemDriver(mkcfg(fmt, False), backend="loop") \
            .fit_tasks(X, np.broadcast_to(y, (CS_ALL.size, n)).copy(),
                       C=CS_ALL)
        t_loop = time.perf_counter() - t0
        loop_t = np.asarray([m.stats.train_time for m in ml])
        loop_it = np.asarray([m.stats.iterations for m in ml])
        records.append({"fmt": fmt, "K": int(CS_ALL.size), "cache": False,
                        "kind": "sequential_sweep",
                        "iterations": int(loop_it.sum()),
                        "wall_s": t_loop,
                        "us_per_iter_problem": (float(loop_t.sum()) * 1e6
                                                / max(loop_it.sum(), 1))})
        for K in Ks:
            Y = np.broadcast_to(y, (K, n)).copy()
            seq_us = float(loop_t[:K].sum()) * 1e6 / max(loop_it[:K].sum(), 1)
            for rc in (False, True):
                mb = None
                for _ in range(2):        # second fit = warm executable
                    mb = MultiProblemDriver(mkcfg(fmt, rc)) \
                        .fit_tasks(X, Y, C=CS_ALL[:K])
                st = mb[0].stats
                tot_it = sum(r["iterations"] for r in st.per_problem)
                # per-problem parity vs the sequential oracle, bitwise
                for k in range(K):
                    assert (st.per_problem[k]["iterations"]
                            == ml[k].stats.iterations), \
                        (fmt, K, rc, k, st.per_problem[k],
                         ml[k].stats.iterations)
                    assert np.array_equal(mb[k].alpha, ml[k].alpha), \
                        (fmt, K, rc, k)
                rec = {
                    "fmt": fmt, "K": K, "cache": rc, "kind": "batched",
                    "iterations": tot_it,
                    "joint_iterations": st.joint_iters,
                    "us_per_iter_problem": (st.train_time * 1e6
                                            / max(tot_it, 1)),
                    "seq_us_per_iter_problem": seq_us,
                    "speedup_vs_sequential": seq_us / (st.train_time * 1e6
                                                       / max(tot_it, 1)),
                    "hit_rate": st.cache_hit_rate,
                    "cache_hits": st.cache_hits,
                    "cache_misses": st.cache_misses,
                    "flops_est": st.flops_est,
                }
                records.append(rec)
                if K >= 4:
                    assert rec["speedup_vs_sequential"] > 1.0, rec
                    if rc:
                        # shared cache: cross-problem reuse must lift the
                        # hit rate above the single-problem baseline
                        assert rec["hit_rate"] > base_hit, (rec, base_hit)
    return records


def multi_csv_lines(records: list[dict]) -> list[str]:
    lines = []
    for r in records:
        if r["kind"] == "single_baseline":
            lines.append(f"multi/{r['fmt']}/K1-baseline,0.0,"
                         f"hit_rate={r['hit_rate']:.3f}")
            continue
        if r["kind"] == "sequential_sweep":
            lines.append(f"multi/{r['fmt']}/sequential,"
                         f"{r['us_per_iter_problem']:.1f},"
                         f"iters={r['iterations']}")
            continue
        tag = "on" if r["cache"] else "off"
        extra = f";hit_rate={r['hit_rate']:.3f}" if r["cache"] else ""
        lines.append(
            f"multi/{r['fmt']}/K{r['K']}/cache-{tag},"
            f"{r['us_per_iter_problem']:.1f},"
            f"iters={r['iterations']};joint={r['joint_iterations']}"
            f";speedup={r['speedup_vs_sequential']:.2f}{extra}")
    return lines


SERVE_BATCHES = (16, 64, 256, 1024)


def _percentiles(fn, repeats: int) -> tuple[float, float]:
    lat = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        lat.append(time.perf_counter() - t0)
    lat = np.asarray(lat)
    return float(np.percentile(lat, 50)), float(np.percentile(lat, 99))


def _assert_sharded_parity(seed: int, n: int, d: int, density: float,
                           eps: float) -> float:
    """Run the 4-device sharded engine in a subprocess (the parent keeps
    one device) and return its max abs deviation from the host oracle."""
    code = f"""
        import numpy as np
        from repro.core import ServeEngine, SMOSolver, SVMConfig
        from repro.data import make_sparse
        X, y = make_sparse({n}, {d}, {density}, seed={seed}, noise=0.05,
                           label_noise=0.0, margin=0.5)
        m = SMOSolver(SVMConfig(C=2.0, sigma2={float(d) / 8.0}, eps={eps},
                                heuristic="multi5pc", chunk_iters=64,
                                min_buffer=64)).fit(X, y)
        rng = np.random.default_rng(0)
        Z = X[rng.integers(0, len(X), 256)].astype(np.float32)
        ref = m.decision_function_host(Z)
        got = ServeEngine(m, shards=4).decision_function(Z)
        print("MAXDIFF", float(np.abs(got - ref).max()))
    """
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=os.path.join(os.path.dirname(
                   os.path.dirname(os.path.abspath(__file__))), "src"))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    diff = float(out.stdout.strip().split("MAXDIFF")[-1])
    assert diff < 1e-3, f"sharded engine diverged: {diff}"
    return diff


def bench_serve(sizes=(1024, 3072), d: int = 384, density: float = 0.05,
                eps: float = 1e-3, seed: int = 3, batches=SERVE_BATCHES,
                repeats: int = 20, host_repeats: int = 3) -> list[dict]:
    """Engine vs host-loop serving latency (see module doc).

    The host path is timed exactly as the seed shipped it — the
    ``decision_function_host`` block loop re-jits per call — because that
    call is what the engine replaces as the model's ``decision_function``.
    """
    records = []
    sharded_diff = None
    for n in sizes:
        X, y = make_sparse(n, d, density, seed=seed, noise=0.05,
                           label_noise=0.0, margin=0.5)
        rng = np.random.default_rng(seed)
        for fmt in ("dense", "ell"):
            cfg = SVMConfig(C=2.0, sigma2=float(d) / 8.0, eps=eps,
                            heuristic="multi5pc", chunk_iters=64,
                            min_buffer=64, format=fmt)
            m = SMOSolver(cfg).fit(X, y)
            mc = m.compact()
            for dtype in ("float32", "bfloat16"):
                eng = ServeEngine(m, dtype=dtype)
                engc = ServeEngine(mc, dtype=dtype)
                for b in batches:
                    Z = X[rng.integers(0, n, b)].astype(np.float32)
                    ref = m.decision_function_host(Z)
                    got = eng.decision_function(Z)      # warms the bucket
                    tol = 6e-2 if dtype == "bfloat16" else 1e-3
                    err = float(np.abs(got - ref).max())
                    assert err < tol, (fmt, dtype, b, err)
                    # compacted artifact scores like the full model
                    errc = float(np.abs(engc.decision_function(Z)
                                        - got).max())
                    assert errc < 1e-4, (fmt, dtype, b, errc)
                    p50, p99 = _percentiles(
                        lambda: eng.decision_function(Z), repeats)
                    h50, _ = _percentiles(
                        lambda: m.decision_function_host(Z), host_repeats)
                    rf = eng.roofline(eng._bucket_of(b)).row()
                    rec = {
                        "n": n, "d": d, "fmt": fmt, "dtype": dtype,
                        "batch": b, "n_sv": eng.n_sv, "m_pad": eng.m_pad,
                        "n_sv_compact": engc.n_sv,
                        "sv_bytes": eng.memory_bytes(),
                        "p50_us": p50 * 1e6, "p99_us": p99 * 1e6,
                        "qps": b / p50,
                        "us_per_query": p50 * 1e6 / b,
                        "host_us_per_query": h50 * 1e6 / b,
                        "speedup_vs_host": h50 / p50,
                        "max_abs_err_vs_host": err,
                        "roofline": {k: rf[k] for k in
                                     ("t_compute_s", "t_memory_s",
                                      "dominant", "useful_ratio")},
                    }
                    records.append(rec)
                    # acceptance: the engine must win at production batches
                    if b >= 64 and dtype == "float32":
                        assert rec["speedup_vs_host"] > 1.0, rec
    sharded_diff = _assert_sharded_parity(seed, sizes[0], d, density, eps)
    records.append({"check": "sharded_vs_single_device",
                    "devices": 4, "max_abs_diff": sharded_diff})
    return records


def _bench_rescale(n: int, repeats: int) -> list[dict]:
    """Elastic-rescale latency in a 4-device subprocess: one saved step
    restored through ``elastic.rescale`` onto 1/2/4-device data meshes
    (host restore + checksum verify + device_put under the new
    NamedShardings — the full checkpoint -> new-mesh path)."""
    code = f"""
        import json, os, tempfile, time
        import numpy as np, jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.ckpt import checkpoint as ck
        from repro.core.parallel import AXIS, data_mesh
        from repro.launch import elastic
        n = {n}
        rng = np.random.default_rng(0)
        group = {{"alpha": rng.random(n).astype(np.float32),
                  "gamma": rng.random(n).astype(np.float32),
                  "active": (rng.random(n) < 0.5).astype(np.int8),
                  "in_buffer": np.ones(n, np.int8)}}
        like = {{k: np.zeros_like(v) for k, v in group.items()}}
        base = tempfile.mkdtemp()
        ck.save(os.path.join(base, "step_9"), 9, {{"svm": group}})
        recs = []
        for p in (1, 2, 4):
            sh = NamedSharding(data_mesh(p), P(AXIS))
            shs = {{"svm": {{k: sh for k in like}}}}
            lat = []
            for _ in range({repeats}):
                t0 = time.perf_counter()
                out, step = elastic.rescale(base, {{"svm": like}}, shs)
                jax.block_until_ready(out["svm"]["alpha"])
                lat.append(time.perf_counter() - t0)
            assert step == 9
            np.testing.assert_array_equal(
                np.asarray(out["svm"]["alpha"]), group["alpha"])
            recs.append({{"check": "rescale", "devices": p, "n": n,
                          "rescale_ms":
                              float(np.percentile(lat, 50)) * 1e3}})
        print("RESCALE" + json.dumps(recs))
    """
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=os.path.join(os.path.dirname(
                   os.path.dirname(os.path.abspath(__file__))), "src"))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().split("RESCALE")[-1])


def bench_elastic(sizes=(1 << 14, 1 << 17), repeats: int = 5,
                  rescale_devices: bool = True) -> list[dict]:
    """Checkpoint-plane latency (see module doc): atomic save and
    verified restore of the epoch driver's step-dir payload — the (n,)
    f32 alpha/gamma masters plus the int8 active/membership masks — as a
    function of n, then the 4-device ``elastic.rescale`` sweep. Each
    timed save overwrites the previous one, so the atomic
    rename-aside-and-publish path is what is measured; restore pays the
    file- and per-array checksum verification, which is the price of
    never resuming from garbage. Round-trip bit-equality is asserted en
    passant."""
    import shutil
    import tempfile
    from repro.ckpt import checkpoint as ck
    records = []
    rng = np.random.default_rng(0)
    for n in sizes:
        group = {"alpha": rng.random(n).astype(np.float32),
                 "gamma": rng.random(n).astype(np.float32),
                 "active": (rng.random(n) < 0.5).astype(np.int8),
                 "in_buffer": np.ones(n, np.int8)}
        like = {k: np.zeros_like(v) for k, v in group.items()}
        payload = sum(v.nbytes for v in group.values())
        tmp = tempfile.mkdtemp()
        try:
            d = os.path.join(tmp, "step_1")
            s50, s99 = _percentiles(
                lambda: ck.save(d, 1, {"svm": group}), repeats)
            assert ck.step_complete(d)
            out = {}
            r50, r99 = _percentiles(
                lambda: out.update(ck.restore(d, "svm", like)), repeats)
            for k in group:
                np.testing.assert_array_equal(np.asarray(out[k]), group[k])
        finally:
            shutil.rmtree(tmp)
        records.append({
            "n": n, "payload_bytes": payload,
            "save_ms": s50 * 1e3, "save_p99_ms": s99 * 1e3,
            "restore_ms": r50 * 1e3, "restore_p99_ms": r99 * 1e3,
            "save_mb_s": payload / max(s50, 1e-9) / 2**20,
            "restore_mb_s": payload / max(r50, 1e-9) / 2**20,
        })
    if rescale_devices:
        records.extend(_bench_rescale(sizes[-1], repeats))
    return records


def elastic_csv_lines(records: list[dict]) -> list[str]:
    lines = []
    for r in records:
        if r.get("check") == "rescale":
            lines.append(f"elastic/rescale/p{r['devices']}/n{r['n']},"
                         f"{r['rescale_ms']:.2f},ms_per_restore")
            continue
        lines.append(
            f"elastic/ckpt/n{r['n']},{r['save_ms']:.2f},"
            f"restore_ms={r['restore_ms']:.2f}"
            f";save_mb_s={r['save_mb_s']:.0f}"
            f";restore_mb_s={r['restore_mb_s']:.0f}"
            f";p99_save_ms={r['save_p99_ms']:.2f}")
    return lines


def serve_csv_lines(records: list[dict]) -> list[str]:
    lines = []
    for r in records:
        if "check" in r:
            lines.append(f"serve/{r['check']},{r['max_abs_diff']:.2e},"
                         f"devices={r['devices']}")
            continue
        lines.append(
            f"serve/{r['fmt']}/{r['dtype']}/nsv{r['n_sv']}/b{r['batch']},"
            f"{r['us_per_query']:.2f},"
            f"host={r['host_us_per_query']:.2f}"
            f";speedup={r['speedup_vs_host']:.2f}"
            f";qps={r['qps']:.0f};p99us={r['p99_us']:.0f}"
            f";dominant={r['roofline']['dominant']}")
    return lines


def epoch_csv_lines(records: list[dict]) -> list[str]:
    lines = []
    for r in records:
        extra = (f";speedup={r['speedup']:.2f}" if "speedup" in r else "")
        lines.append(
            f"epoch/{r['fmt']}/n{r['n']}/k{r['fuse_iters']},"
            f"{r['us_per_iter']:.1f},"
            f"iters={r['iterations']};dispatches={r['dispatches']}{extra}")
    return lines


def recon_csv_lines(records: list[dict]) -> list[str]:
    lines = []
    for r in records:
        extra = (f";speedup={r['speedup']:.2f}" if "speedup" in r else "")
        lines.append(
            f"recon/{r['fmt']}/n{r['n']}/{r['mirror']},"
            f"{r['us_per_recon']:.1f},"
            f"recons={r['reconstructions']};iters={r['iterations']}{extra}")
    return lines


def compact_csv_lines(records: list[dict]) -> list[str]:
    lines = []
    for r in records:
        extra = (f";speedup={r['speedup']:.2f}" if "speedup" in r else "")
        lines.append(
            f"compact/{r['fmt']}/m{r['n']}/{r['backend']},"
            f"{r['us_per_compact']:.1f},"
            f"compactions={r['compactions']};iters={r['iterations']}{extra}")
    return lines


def cache_csv_lines(records: list[dict]) -> list[str]:
    lines = []
    for r in records:
        tag = "on" if r["cache"] else "off"
        extra = (f";hit_rate={r['hit_rate']:.3f}"
                 f";speedup={r.get('speedup', 1.0):.2f}" if r["cache"]
                 else "")
        lines.append(
            f"cache/{r['fmt']}/{tag},{r['us_per_iter']:.1f},"
            f"iters={r['iterations']};flops={r['flops_est']:.3g}{extra}")
    return lines


def csv_lines(records: list[dict]) -> list[str]:
    lines = []
    for r in records:
        if "n_classes" in r:
            lines.append(
                f"sparse/{r['spec']}/ovr{r['n_classes']}/{r['fmt']},"
                f"{r['us_per_iter_problem']:.1f},"
                f"iters={r['iterations']};obj={r['obj']:.4f}"
                f";n_sv_union={r['n_sv_union']}")
            continue
        extra = "" if r["fmt"] == "dense" else (
            f";K={r['buffer_K'][0]};K_min={min(r['buffer_K'])}"
            f";mem_ratio={r['mem_ratio']:.3f}")
        tag = r.get("spec", f"{r['density']:g}")
        lines.append(
            f"sparse/{tag}/{r['fmt']},{r['us_per_iter']:.1f},"
            f"iters={r['iterations']};mem_bytes={r['mem_bytes']}"
            f";obj={r['obj']:.4f}{extra}")
    return lines


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None,
                    help="write the sparse sweep as a JSON artifact")
    ap.add_argument("--cache-out", default=None,
                    help="run the row-cache on/off sweep and write it as a "
                         "JSON artifact (BENCH_cache.json in CI)")
    ap.add_argument("--compact-out", default=None,
                    help="run the host-vs-device compaction latency sweep "
                         "and write it as a JSON artifact "
                         "(BENCH_compact.json in CI)")
    ap.add_argument("--recon-out", default=None,
                    help="run the host-streaming vs device-mirror Alg. 6 "
                         "latency sweep and write it as a JSON artifact "
                         "(BENCH_reconstruct.json in CI)")
    ap.add_argument("--epoch-out", default=None,
                    help="run the fused-epoch fuse_iters sweep and write it "
                         "as a JSON artifact (BENCH_epoch.json in CI)")
    ap.add_argument("--serve-out", default=None,
                    help="run the serving engine-vs-host-loop sweep and "
                         "write it as a JSON artifact (BENCH_serve.json "
                         "in CI)")
    ap.add_argument("--multi-out", default=None,
                    help="run the batched-vs-sequential multi-problem "
                         "sweep and write it as a JSON artifact "
                         "(BENCH_multi.json in CI)")
    ap.add_argument("--elastic-out", default=None,
                    help="run the checkpoint save/restore + elastic "
                         "rescale latency sweep and write it as a JSON "
                         "artifact (BENCH_elastic.json in CI)")
    ap.add_argument("--quick", action="store_true",
                    help="smaller problems (CI-budget run)")
    args = ap.parse_args(argv)
    if args.out or not (args.cache_out or args.compact_out
                        or args.recon_out or args.epoch_out
                        or args.serve_out or args.multi_out
                        or args.elastic_out):
        kw = dict(n=512, d=1024) if args.quick else {}
        records = bench_sparse(quick=args.quick, **kw)
        for line in csv_lines(records):
            print(line, flush=True)
        if args.out:
            with open(args.out, "w") as f:
                json.dump({"bench": "sparse", "records": records}, f,
                          indent=1)
            print(f"wrote {args.out}", flush=True)
    if args.cache_out:
        kw = (dict(n=2048, d=768, slots=1024, eps=1e-5) if args.quick
              else {})
        cache_records = bench_cache(**kw)
        for line in cache_csv_lines(cache_records):
            print(line, flush=True)
        with open(args.cache_out, "w") as f:
            json.dump({"bench": "row_cache", "records": cache_records}, f,
                      indent=1)
        print(f"wrote {args.cache_out}", flush=True)
    if args.compact_out:
        kw = dict(sizes=(1024, 4096), d=256) if args.quick else {}
        compact_records = bench_compact(**kw)
        for line in compact_csv_lines(compact_records):
            print(line, flush=True)
        with open(args.compact_out, "w") as f:
            json.dump({"bench": "compaction", "records": compact_records}, f,
                      indent=1)
        print(f"wrote {args.compact_out}", flush=True)
    if args.recon_out:
        kw = dict(sizes=(1024, 1536), d=256) if args.quick else {}
        recon_records = bench_recon(**kw)
        for line in recon_csv_lines(recon_records):
            print(line, flush=True)
        with open(args.recon_out, "w") as f:
            json.dump({"bench": "reconstruction", "records": recon_records},
                      f, indent=1)
        print(f"wrote {args.recon_out}", flush=True)
    if args.epoch_out:
        kw = dict(sizes=(1024, 2048), d=256) if args.quick else {}
        epoch_records = bench_epoch(**kw)
        for line in epoch_csv_lines(epoch_records):
            print(line, flush=True)
        with open(args.epoch_out, "w") as f:
            json.dump({"bench": "fused_epoch", "records": epoch_records},
                      f, indent=1)
        print(f"wrote {args.epoch_out}", flush=True)
    if args.serve_out:
        kw = (dict(sizes=(768, 1536), d=256, batches=(16, 64, 256),
                   repeats=10) if args.quick else {})
        serve_records = bench_serve(**kw)
        for line in serve_csv_lines(serve_records):
            print(line, flush=True)
        with open(args.serve_out, "w") as f:
            json.dump({"bench": "serve", "records": serve_records},
                      f, indent=1)
        print(f"wrote {args.serve_out}", flush=True)
    if args.multi_out:
        kw = dict(n=512, d=192, slots=512) if args.quick else {}
        multi_records = bench_multi(**kw)
        for line in multi_csv_lines(multi_records):
            print(line, flush=True)
        with open(args.multi_out, "w") as f:
            json.dump({"bench": "multi_problem", "records": multi_records},
                      f, indent=1)
        print(f"wrote {args.multi_out}", flush=True)
    if args.elastic_out:
        kw = (dict(sizes=(1 << 13, 1 << 15), repeats=3) if args.quick
              else {})
        elastic_records = bench_elastic(**kw)
        for line in elastic_csv_lines(elastic_records):
            print(line, flush=True)
        with open(args.elastic_out, "w") as f:
            json.dump({"bench": "elastic", "records": elastic_records},
                      f, indent=1)
        print(f"wrote {args.elastic_out}", flush=True)


if __name__ == "__main__":
    main()
