"""Sparse-format benchmark: ELL vs dense training storage (paper Fig. 1b).

For each density in the sweep, builds a ``make_sparse`` dataset and reports

  * buffer memory of the dense vs block-ELL training buffers (the paper's
    space-conservation argument, extended to our TPU block-ELL layout), and
  * per-SMO-iteration wall time for both formats (same heuristic, same
    convergence target), i.e. what the sparse data plane costs/saves in the
    gamma-update hot loop.

CSV rows: ``sparse/<density>/<fmt>,us_per_iter,derived``.
"""
from __future__ import annotations

import numpy as np

from repro.core import SMOSolver, SVMConfig, dataplane
from repro.data import make_sparse

DENSITIES = (0.01, 0.05, 0.25)


def bench_sparse(n: int = 1024, d: int = 2048, densities=DENSITIES,
                 heuristic: str = "single1000", eps: float = 1e-3,
                 seed: int = 0) -> list[str]:
    lines = []
    for rho in densities:
        X, y = make_sparse(n, d, rho, seed=seed)
        mem = {}
        models = {}
        for fmt in ("dense", "ell"):
            cfg = SVMConfig(C=4.0, sigma2=float(d) / 8.0, eps=eps,
                            heuristic=heuristic, chunk_iters=256,
                            format=fmt)
            solver = SMOSolver(cfg)
            m = solver.fit(X, y)
            models[fmt] = m
            store = solver._store
            buf = store.alloc(m.stats.buffer_sizes[0])
            import jax.numpy as jnp
            mem[fmt] = store.to_device(buf, jnp.asarray).memory_bytes()
            us = (m.stats.train_time / max(m.stats.iterations, 1)) * 1e6
            extra = "" if fmt == "dense" else \
                f";K={store.K};mem_ratio={mem['ell'] / mem['dense']:.3f}"
            lines.append(
                f"sparse/{rho:g}/{fmt},{us:.1f},"
                f"iters={m.stats.iterations};mem_bytes={mem[fmt]}"
                f";obj={m.dual_objective():.4f}{extra}")
        rel = abs(models["ell"].dual_objective() -
                  models["dense"].dual_objective()) / \
            max(abs(models["dense"].dual_objective()), 1e-9)
        assert rel < 1e-2, f"ELL/dense objective diverged at rho={rho}: {rel}"
    return lines


if __name__ == "__main__":
    for line in bench_sparse():
        print(line, flush=True)
