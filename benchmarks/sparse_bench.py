"""Sparse-format + kernel-row-cache benchmarks.

Sparse sweep: ELL vs dense training storage (paper Fig. 1b).

For each density in the sweep, builds a ``make_sparse`` dataset and trains
three configurations of the same problem:

  * ``dense``        — dense sample buffers,
  * ``ell-fixed``    — block-ELL with the PR-1 behavior: K pinned to the
                       store-wide ingest budget (``ell_adaptive=False``),
  * ``ell-adaptive`` — block-ELL with per-buffer K recompaction (the lane
                       budget tracks the surviving rows at every physical
                       compaction).

Reported per configuration: buffer memory of the initial training buffer,
per-SMO-iteration wall time, iteration count, dual objective, and for ELL
runs the K trajectory across buffer builds. CSV rows (stdout) keep the
historical ``sparse/<density>/<fmt>,us_per_iter,derived`` shape; ``--out``
additionally writes the full sweep as a JSON artifact (``BENCH_sparse.json``
in CI) so the perf trajectory accumulates across PRs.

Cache sweep (``--cache-out`` -> ``BENCH_cache.json``): trains a
repeat-heavy workload — a long low-tolerance convergence tail bouncing
inside a hot working set, the access pattern the device-resident LRU
kernel-row cache exists for — with the cache off and on, for both storage
formats. Reports hit rate, us/iter, and the cache-aware FLOP estimate, and
asserts the exactness contract (identical iteration counts) en passant.
"""
from __future__ import annotations

import argparse
import json

from repro.core import SMOSolver, SVMConfig
from repro.data import make_repeat_heavy, make_sparse

DENSITIES = (0.01, 0.05, 0.25)

CONFIGS = (
    ("dense", dict(format="dense")),
    ("ell-fixed", dict(format="ell", ell_adaptive=False)),
    ("ell-adaptive", dict(format="ell", ell_adaptive=True)),
)


def bench_sparse(n: int = 1024, d: int = 2048, densities=DENSITIES,
                 heuristic: str = "single1000", eps: float = 1e-3,
                 seed: int = 0) -> list[dict]:
    import jax.numpy as jnp
    records = []
    for rho in densities:
        X, y = make_sparse(n, d, rho, seed=seed)
        by_name = {}
        for name, overrides in CONFIGS:
            cfg = SVMConfig(C=4.0, sigma2=float(d) / 8.0, eps=eps,
                            heuristic=heuristic, chunk_iters=256,
                            **overrides)
            solver = SMOSolver(cfg)
            m = solver.fit(X, y)
            store = solver._store
            buf = store.alloc(m.stats.buffer_sizes[0],
                              m.stats.buffer_K[0] if m.stats.buffer_K
                              else None)
            mem = store.to_device(buf, jnp.asarray).memory_bytes()
            rec = {
                "density": rho, "fmt": name, "n": n, "d": d,
                "us_per_iter": (m.stats.train_time /
                                max(m.stats.iterations, 1)) * 1e6,
                "iterations": m.stats.iterations,
                "mem_bytes": mem,
                "obj": m.dual_objective(),
                "compactions": m.stats.compactions,
                "buffer_K": list(m.stats.buffer_K),
            }
            by_name[name] = rec
            records.append(rec)
        ref = by_name["dense"]["obj"]
        for name in ("ell-fixed", "ell-adaptive"):
            rel = abs(by_name[name]["obj"] - ref) / max(abs(ref), 1e-9)
            assert rel < 1e-2, \
                f"{name}/dense objective diverged at rho={rho}: {rel}"
            by_name[name]["mem_ratio"] = \
                by_name[name]["mem_bytes"] / by_name["dense"]["mem_bytes"]
    return records


def bench_cache(n: int = 3072, d: int = 768, density: float = 0.25,
                eps: float = 1e-5, slots: int = 2048,
                seed: int = 1) -> list[dict]:
    """Row-cache on/off sweep on a repeat-heavy workload (see module doc).

    Sized so the row pass dominates the cache machinery even in --quick
    mode: shrinking the problem much below (2048, 768) makes the win
    vanish into fixed per-iteration overhead and the artifact stops
    demonstrating anything.
    """
    X, y = make_repeat_heavy(n, d, density, seed=seed)
    records = []
    for fmt in ("dense", "ell"):
        by_cache = {}
        for rc in (False, True):
            cfg = SVMConfig(C=8.0, sigma2=float(d) / 8.0, eps=eps,
                            heuristic="original", chunk_iters=512,
                            format=fmt, row_cache=rc, row_cache_slots=slots)
            m = SMOSolver(cfg).fit(X, y)
            rec = {
                "fmt": fmt, "cache": rc, "n": n, "d": d,
                "density": density, "eps": eps, "slots": slots,
                "us_per_iter": (m.stats.train_time /
                                max(m.stats.iterations, 1)) * 1e6,
                "iterations": m.stats.iterations,
                "hit_rate": m.stats.cache_hit_rate,
                "cache_hits": m.stats.cache_hits,
                "cache_misses": m.stats.cache_misses,
                "flops_est": m.stats.flops_est,
                "obj": m.dual_objective(),
            }
            by_cache[rc] = rec
            records.append(rec)
        # the cache is exact: identical trajectories by construction
        assert by_cache[True]["iterations"] == by_cache[False]["iterations"], \
            (fmt, by_cache)
        assert by_cache[True]["hit_rate"] > 0.0, (fmt, by_cache)
        by_cache[True]["speedup"] = (by_cache[False]["us_per_iter"] /
                                     by_cache[True]["us_per_iter"])
    return records


def cache_csv_lines(records: list[dict]) -> list[str]:
    lines = []
    for r in records:
        tag = "on" if r["cache"] else "off"
        extra = (f";hit_rate={r['hit_rate']:.3f}"
                 f";speedup={r.get('speedup', 1.0):.2f}" if r["cache"]
                 else "")
        lines.append(
            f"cache/{r['fmt']}/{tag},{r['us_per_iter']:.1f},"
            f"iters={r['iterations']};flops={r['flops_est']:.3g}{extra}")
    return lines


def csv_lines(records: list[dict]) -> list[str]:
    lines = []
    for r in records:
        extra = "" if r["fmt"] == "dense" else (
            f";K={r['buffer_K'][0]};K_min={min(r['buffer_K'])}"
            f";mem_ratio={r['mem_ratio']:.3f}")
        lines.append(
            f"sparse/{r['density']:g}/{r['fmt']},{r['us_per_iter']:.1f},"
            f"iters={r['iterations']};mem_bytes={r['mem_bytes']}"
            f";obj={r['obj']:.4f}{extra}")
    return lines


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None,
                    help="write the sparse sweep as a JSON artifact")
    ap.add_argument("--cache-out", default=None,
                    help="run the row-cache on/off sweep and write it as a "
                         "JSON artifact (BENCH_cache.json in CI)")
    ap.add_argument("--quick", action="store_true",
                    help="smaller problems (CI-budget run)")
    args = ap.parse_args(argv)
    if args.out or not args.cache_out:
        kw = dict(n=512, d=1024) if args.quick else {}
        records = bench_sparse(**kw)
        for line in csv_lines(records):
            print(line, flush=True)
        if args.out:
            with open(args.out, "w") as f:
                json.dump({"bench": "sparse", "records": records}, f,
                          indent=1)
            print(f"wrote {args.out}", flush=True)
    if args.cache_out:
        kw = (dict(n=2048, d=768, slots=1024, eps=1e-5) if args.quick
              else {})
        cache_records = bench_cache(**kw)
        for line in cache_csv_lines(cache_records):
            print(line, flush=True)
        with open(args.cache_out, "w") as f:
            json.dump({"bench": "row_cache", "records": cache_records}, f,
                      indent=1)
        print(f"wrote {args.cache_out}", flush=True)


if __name__ == "__main__":
    main()
