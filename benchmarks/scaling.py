"""Process-scaling benchmark (the x-axis of the paper's Figs. 2-6).

The container has one physical core, so wall-clock cannot show real
multi-device speedup; what IS hardware-independent and reported here:

  * per-shard work (kernel-row evaluations / p) — the Theta(lambda*N/p)
    term the paper's scaling rests on,
  * the iteration count (identical across p — the distributed algorithm is
    exact, so parallelism divides work without adding iterations),
  * collective volume per iteration (the all_gather payload, 2d+6 floats).

Each device count runs in a subprocess with its own XLA host-device flag.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_CODE = """
import json
import numpy as np
from repro.core import SVMConfig
from repro.core.parallel import ParallelSMOSolver
from repro.data import make, SPECS

spec = SPECS['a9a']
X, y, Xt, yt = make('a9a', scale=0.04, seed=0)
m = ParallelSMOSolver(SVMConfig(C=spec.C, sigma2=spec.sigma2,
                                heuristic='{h}', chunk_iters=256,
                                min_buffer=128)).fit(X, y)
import jax
p = len(jax.devices())
krows = m.stats.flops_est / (4.0 * X.shape[1] + 10.0)
print(json.dumps(dict(p=p, iters=m.stats.iterations,
                      krows_per_shard=krows / p,
                      obj=m.dual_objective(),
                      bcast_floats=(2 * X.shape[1] + 6) * m.stats.iterations,
                      time=m.stats.train_time + m.stats.recon_time)))
"""


def bench_scaling(device_counts=(1, 2, 4, 8), heuristic="multi5pc"):
    rows = []
    ref_obj = None
    for p in device_counts:
        env = dict(os.environ,
                   XLA_FLAGS=f"--xla_force_host_platform_device_count={p}",
                   PYTHONPATH=os.path.join(ROOT, "src"))
        out = subprocess.run(
            [sys.executable, "-c", textwrap.dedent(_CODE.format(h=heuristic))],
            capture_output=True, text=True, env=env, timeout=900)
        if out.returncode != 0:
            rows.append(f"scaling_a9a/p{p},0,error")
            continue
        r = json.loads(out.stdout.strip().splitlines()[-1])
        if ref_obj is None:
            ref_obj = r["obj"]
        rows.append(
            f"scaling_a9a/p{p},{r['time'] * 1e6:.0f},"
            f"iters={r['iters']};krows_per_shard={r['krows_per_shard']:.3e};"
            f"obj_drift={abs(r['obj'] - ref_obj) / abs(ref_obj):.2e};"
            f"bcast_floats={r['bcast_floats']:.3e}")
    return rows
