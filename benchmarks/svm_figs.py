"""Paper-figure benchmarks: heuristic sweeps per dataset (Figs. 2-8),
the Fig. 9 summary, and the Fig. 1b CSR space table.

Datasets are the synthetic stand-ins (repro.data.synthetic) scaled to
CPU-tractable sizes; the quantities the paper plots — training time split
into optimization + gamma-reconstruction, per heuristic — are reported
directly, plus hardware-independent work counts (iterations and kernel-row
evaluations) so the heuristic comparison is robust to the 1-core container.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import SMOSolver, SVMConfig
from repro.data import SPECS, csr_space_report, make

# CPU-tractable scale per dataset (paper sizes are 7k-60k samples)
SCALES = {
    "a7a": 0.08, "a9a": 0.04, "usps": 0.15, "mushrooms": 0.15,
    "w7a": 0.05, "ijcnn": 0.03, "mnist": 0.02,
}

DEFAULT_HEURISTICS = ["original", "single500", "single5pc", "single10pc",
                      "multi2", "multi500", "multi1000", "multi5pc",
                      "multi10pc", "multi50pc"]


@dataclasses.dataclass
class BenchRow:
    dataset: str
    heuristic: str
    train_time: float
    recon_time: float
    iterations: int
    kernel_rows: float     # gamma-update row evaluations (work proxy)
    n_sv: int
    accuracy: float
    speedup_vs_original: float = 1.0

    def csv(self) -> str:
        us = (self.train_time + self.recon_time) * 1e6
        derived = (f"speedup={self.speedup_vs_original:.2f}x;"
                   f"recon_share={self.recon_time / max(self.train_time + self.recon_time, 1e-9):.2f};"
                   f"iters={self.iterations};nsv={self.n_sv};"
                   f"acc={self.accuracy:.4f}")
        return f"fig_{self.dataset}/{self.heuristic},{us:.0f},{derived}"


def bench_dataset(name: str, heuristics=None, scale=None, seed=0,
                  eps=1e-3) -> list[BenchRow]:
    spec = SPECS[name]
    X, y, Xt, yt = make(name, scale=scale or SCALES[name], seed=seed)
    rows = []
    base_time = None
    for h in heuristics or DEFAULT_HEURISTICS:
        cfg = SVMConfig(C=spec.C, sigma2=spec.sigma2, eps=eps, heuristic=h,
                        chunk_iters=256, min_buffer=128)
        SMOSolver(cfg).fit(X, y)        # warm the jit caches (runner +
        m = SMOSolver(cfg).fit(X, y)    # bucket shapes); report 2nd run
        acc = float((m.predict(Xt) == yt).mean()) if len(yt) else float("nan")
        krows = m.stats.flops_est / max(4.0 * X.shape[1] + 10.0, 1.0)
        row = BenchRow(name, h, m.stats.train_time, m.stats.recon_time,
                       m.stats.iterations, krows, m.stats.n_sv, acc)
        if h == "original":
            base_time = row.train_time + row.recon_time
        if base_time:
            row.speedup_vs_original = base_time / max(
                row.train_time + row.recon_time, 1e-9)
        rows.append(row)
    return rows


def fig9_summary(results: dict[str, list[BenchRow]]) -> list[str]:
    """Best-heuristic speedup vs Original + accuracy, per dataset."""
    out = []
    for ds, rows in results.items():
        orig = next(r for r in rows if r.heuristic == "original")
        best = max(rows, key=lambda r: r.speedup_vs_original)
        derived = (f"best={best.heuristic};speedup={best.speedup_vs_original:.2f}x;"
                   f"acc_best={best.accuracy:.4f};acc_orig={orig.accuracy:.4f};"
                   f"sv_frac={best.n_sv / max(orig.iterations, 1):.3f}")
        out.append(f"fig9_summary/{ds},"
                   f"{(best.train_time + best.recon_time) * 1e6:.0f},{derived}")
    return out


def fig1b_space() -> list[str]:
    out = []
    for name in ("a7a", "w7a", "mushrooms", "usps", "mnist", "ijcnn"):
        X, _, _, _ = make(name, scale=min(SCALES[name], 0.05))
        rep = csr_space_report(X)
        out.append(
            f"fig1b_space/{name},0,"
            f"density={rep['density']:.3f};csr_saving={rep['csr_saving_pct']:.1f}%;"
            f"ell_saving={rep['ell_saving_pct']:.1f}%")
    return out
